//! Workload engine: FHE-op traces → latency / energy / power on an
//! [`ArchConfig`], through the §IV mapping (subarray-group layout,
//! bank-level pipeline stages, load-save rounds).
//!
//! Reported quantities follow §V-C: per-input time is the *bottleneck
//! pipeline-stage latency* when the pipeline is full, times the number of
//! load-save rounds, divided by the concurrent pipelines that fit in
//! memory.

use super::config::ArchConfig;
use super::cost::{Breakdown, Cost, CostModel, FheShape};
use crate::trace::{FheOp, Trace};

/// Mapping/optimization switches (Fig. 15 ablations).
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Montgomery-friendly moduli (§IV-B). Off = Base0.
    pub montgomery: bool,
    /// Customized inter-bank chain network (§III-C). Off = Base1.
    pub interbank_chain: bool,
    /// Load-save pipeline mapping (§IV-F3). Off = Base2-style naive.
    pub load_save: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            montgomery: true,
            interbank_chain: true,
            load_save: true,
        }
    }
}

/// Simulation output for one (config, trace, options) point.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub config: ArchConfig,
    pub workload: &'static str,
    /// Seconds per input with the pipeline full.
    pub latency_s: f64,
    /// Energy per input, joules.
    pub energy_j: f64,
    /// Average power during steady state, W.
    pub power_w: f64,
    pub area_mm2: f64,
    pub breakdown: Breakdown,
}

impl SimResult {
    pub fn edp(&self) -> f64 {
        self.energy_j * self.latency_s
    }
    pub fn edap(&self) -> f64 {
        self.edp() * self.area_mm2
    }
    pub fn throughput(&self) -> f64 {
        1.0 / self.latency_s
    }
}

/// Per-op breakdown on one bank-partition (group-parallel over limbs).
fn op_breakdown(model: &CostModel, cfg: &ArchConfig, op: FheOp, opts: &SimOptions) -> Breakdown {
    let l = model.shape.limbs as f64;
    let k = model.shape.k_special as f64;
    // Limb-level parallelism within one allocation unit (bank): each
    // subarray group holds one residue poly (§IV-A).
    let groups = (cfg.subarrays_per_bank() / 16).max(1) as f64;
    let pf = groups.min(l + k);
    let chain = opts.interbank_chain;
    let mut bd = match op {
        FheOp::HAdd => model.modadd_poly().scaled(2.0 * l),
        FheOp::PMul => {
            let mut b = model.modmul_poly().scaled(2.0 * l);
            b.add(&model.modmul_poly().scaled(l)); // rescale fused
            b
        }
        FheOp::Rescale => model.modmul_poly().scaled(2.0 * l),
        FheOp::HMul => {
            let mut b = model.modmul_poly().scaled(4.0 * l); // tensor
            b.add(&model.keyswitch(chain));
            b.add(&model.modmul_poly().scaled(2.0 * l)); // rescale
            b
        }
        FheOp::HRot => {
            let mut b = model.automorphism_poly().scaled(2.0 * l);
            b.add(&model.keyswitch(chain));
            b
        }
        FheOp::Bootstrap => unreachable!("expand_bootstrap first"),
    };
    // Divide group-parallel categories by pf; interbank scales with the
    // concurrent chain links in a channel (§III-C).
    bd.computation = bd.computation.scaled(1.0 / pf);
    bd.permutation = bd.permutation.scaled(1.0 / pf);
    bd.read_write = bd.read_write.scaled(1.0 / pf);
    bd
}

/// Simulate one workload trace on one configuration.
pub fn simulate(cfg: &ArchConfig, trace: &Trace, opts: SimOptions) -> SimResult {
    let trace = trace.expand_bootstrap();
    let shape = FheShape {
        log_n: trace.log_n,
        limbs: trace.limbs,
        k_special: if trace.log_n >= 16 { 6 } else { 1 },
        dnum: if trace.log_n >= 16 { 4 } else { 1 },
        mult_shifts: if opts.montgomery { 3 } else { 64 },
    };
    let model = CostModel::new(cfg, shape);

    // ---- pipeline staging (§IV-F): ops round-robin over banks ----
    let partitions = cfg.total_banks() as usize;
    let stages = trace.ops.len().min(partitions).max(1);
    let mut stage_bd: Vec<Breakdown> = vec![Breakdown::default(); stages];
    let mut total_bd = Breakdown::default();
    for (i, &op) in trace.ops.iter().enumerate() {
        let bd = op_breakdown(&model, cfg, op, &opts);
        stage_bd[i % stages].add(&bd);
        total_bd.add(&bd);
    }

    // Inter-stage ciphertext transfer: one ct (2·L·N·8 bytes) per stage
    // hop via channel/stack IO.
    let ct_bytes = 2.0 * trace.limbs as f64 * (1u64 << trace.log_n) as f64 * 8.0;
    let hop_ns = ct_bytes / (cfg.stack_bisection_gbps() * 1e9) * 1e9;
    let hop_cycles = hop_ns / cfg.cycle_ns();
    let hop_energy = ct_bytes * 8.0 * cfg.e_io_pj_per_bit();
    for bd in stage_bd.iter_mut() {
        bd.stack.add(Cost::new(hop_cycles, hop_energy));
        total_bd.stack.add(Cost::new(hop_cycles, hop_energy));
    }

    // ---- constant loading (load-save pipeline, §IV-F3) ----
    // Constants = plaintext weights + the distinct key-switching keys
    // the trace touches (relin + rotation keys; capped at the distinct
    // key estimate). Naive mapping reloads per input; load-save loads
    // once per round and amortizes over the batch (Fig. 11).
    let ks_ops = trace
        .ops
        .iter()
        .filter(|o| matches!(o, FheOp::HMul | FheOp::HRot))
        .count() as f64;
    let distinct_keys = ks_ops.min(64.0);
    let key_bytes = distinct_keys * model.evk_bytes();
    let const_bits = (trace.const_bytes + key_bytes) * 8.0;
    let io_bw_bits = cfg.interstack_gbps() * 8.0 * 1e9; // external feed
    let load_cycles_full = const_bits / io_bw_bits * 1e9 / cfg.cycle_ns();
    let (load_cycles, load_energy) = if opts.load_save {
        (
            load_cycles_full / trace.batch as f64,
            const_bits * cfg.e_io_pj_per_bit() / trace.batch as f64,
        )
    } else {
        // every stage re-loads its constants for every input
        (load_cycles_full, const_bits * cfg.e_io_pj_per_bit())
    };
    total_bd.channel.add(Cost::new(load_cycles, load_energy));

    // ---- bottleneck stage = per-input latency when pipeline is full ----
    let bottleneck = stage_bd
        .iter()
        .map(|b| b.total().cycles)
        .fold(0.0f64, f64::max)
        + load_cycles;

    // Multiple independent pipelines when memory allows (§V-C).
    let pipeline_mem = ct_bytes * trace.ops.len().min(partitions) as f64 * 3.0
        + trace.const_bytes;
    let pipelines = ((cfg.capacity_bytes() as f64 * 0.6) / pipeline_mem)
        .floor()
        .max(1.0);

    let latency_s = bottleneck * cfg.cycle_ns() * 1e-9 / pipelines;
    let energy_j = total_bd.total().energy_pj * 1e-12;
    let power_w = if latency_s > 0.0 {
        // steady-state: energy of one input / time of one input, plus
        // peripheral/static power.
        energy_j / (bottleneck * cfg.cycle_ns() * 1e-9)
            + super::area::peripheral_power_w(cfg)
    } else {
        0.0
    };

    SimResult {
        config: *cfg,
        workload: trace.name,
        latency_s,
        energy_j,
        power_w,
        area_mm2: super::area::total_area_mm2(cfg),
        breakdown: total_bd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::workloads;

    #[test]
    fn higher_ar_is_faster() {
        let t = workloads::helr();
        let mut last = f64::MAX;
        for ar in [1u32, 2, 4, 8] {
            let r = simulate(&ArchConfig::new(ar, 4096), &t, SimOptions::default());
            assert!(r.latency_s < last, "AR{ar}: {} !< {last}", r.latency_s);
            last = r.latency_s;
        }
    }

    #[test]
    fn montgomery_ablation_helps_low_ar_most() {
        // Fig. 15(1): ~1.68× on ARx2, shrinking at higher AR.
        let t = workloads::helr();
        let speedup = |ar: u32| {
            let base = simulate(
                &ArchConfig::new(ar, 2048),
                &t,
                SimOptions {
                    montgomery: false,
                    ..Default::default()
                },
            );
            let opt = simulate(&ArchConfig::new(ar, 2048), &t, SimOptions::default());
            base.latency_s / opt.latency_s
        };
        let s2 = speedup(2);
        assert!(s2 > 1.2, "ARx2 montgomery speedup {s2}");
    }

    #[test]
    fn interbank_chain_ablation_helps() {
        // Fig. 15(2): 1.31–2.12× across ARs.
        let t = workloads::bootstrapping();
        let cfg = ArchConfig::new(4, 4096);
        let base = simulate(
            &cfg,
            &t,
            SimOptions {
                interbank_chain: false,
                ..Default::default()
            },
        );
        let opt = simulate(&cfg, &t, SimOptions::default());
        let s = base.latency_s / opt.latency_s;
        assert!(s > 1.05, "chain speedup {s}");
    }

    #[test]
    fn load_save_ablation_helps() {
        // Fig. 15(3): 1.15–3.59×.
        let t = workloads::helr();
        let cfg = ArchConfig::new(8, 8192);
        let base = simulate(
            &cfg,
            &t,
            SimOptions {
                load_save: false,
                ..Default::default()
            },
        );
        let opt = simulate(&cfg, &t, SimOptions::default());
        let s = base.latency_s / opt.latency_s;
        assert!(s > 1.1, "load-save speedup {s}");
    }

    #[test]
    fn energy_and_power_positive_and_bounded() {
        for t in workloads::all() {
            let r = simulate(&ArchConfig::default(), &t, SimOptions::default());
            assert!(r.latency_s > 0.0 && r.energy_j > 0.0);
            assert!(
                r.power_w > 1.0 && r.power_w < 2000.0,
                "{}: {} W",
                t.name,
                r.power_w
            );
        }
    }

    #[test]
    fn breakdown_totals_are_consistent() {
        let t = workloads::resnet20();
        let r = simulate(&ArchConfig::default(), &t, SimOptions::default());
        let sum = r.breakdown.computation.cycles
            + r.breakdown.permutation.cycles
            + r.breakdown.read_write.cycles
            + r.breakdown.interbank.cycles
            + r.breakdown.channel.cycles
            + r.breakdown.stack.cycles;
        assert!((sum - r.breakdown.total().cycles).abs() < 1.0);
    }
}
