//! Area and power model (paper Table III + §VI-B anchors).
//!
//! Component model anchored at the published AR×4/4k breakdown for one
//! 16 GB HBM2E stack, with AR and adder-width scaling:
//! sense amps / local WL drivers grow with subarray count (∝ AR), the
//! near-mat adders & latches with `AR × width`, HDLs with AR. Calibrated
//! against §VI-B: AR×1-1k ⇒ 223.81 mm², AR×8-8k ⇒ 642.32 mm² total
//! (2 stacks), AR×4-4k ⇒ ~367 mm².

use super::config::ArchConfig;

/// Per-stack area breakdown in mm² (single layer, Table III layout).
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    pub dram_cell: f64,
    pub lwl_driver: f64,
    pub sense_amp: f64,
    pub decoders: f64,
    pub center_bus: f64,
    pub data_bus: f64,
    pub tsv: f64,
    pub hdl: f64,
    pub adders_latches: f64,
    pub chain: f64,
    pub control: f64,
}

impl AreaBreakdown {
    pub fn dram_total(&self) -> f64 {
        self.dram_cell
            + self.lwl_driver
            + self.sense_amp
            + self.decoders
            + self.center_bus
            + self.data_bus
            + self.tsv
    }

    pub fn custom_total(&self) -> f64 {
        self.hdl + self.adders_latches + self.chain + self.control
    }

    pub fn stack_total(&self) -> f64 {
        self.dram_total() + self.custom_total()
    }
}

/// Table III component model for one 16 GB stack.
pub fn stack_area(cfg: &ArchConfig) -> AreaBreakdown {
    let ar = cfg.ar as f64;
    let w = cfg.adder_width as f64;
    AreaBreakdown {
        dram_cell: 56.54,
        // LWL drivers grow mildly with subarray count.
        lwl_driver: 26.15 * (0.5 + ar / 8.0),
        // Sense amps ∝ subarrays (anchored at AR×4).
        sense_amp: 45.63 * (ar / 4.0),
        decoders: 0.39,
        center_bus: 1.56,
        data_bus: 4.81,
        tsv: 13.25,
        // HDLs: one set per subarray row (∝ AR), anchored AR×4 = 14.13.
        hdl: 14.13 * (ar / 4.0),
        // Adders & latches ∝ total adders = subarrays × width;
        // anchored AR×4, 4k = 30.43 mm² (coefficient trimmed slightly to
        // land the published AR×8-8k total).
        adders_latches: 27.0 * (ar / 4.0) * (w / 4096.0),
        chain: 0.065,
        control: 0.56,
    }
}

/// Total chip area in mm² (paper reports 2-stack totals in §VI-B).
pub fn total_area_mm2(cfg: &ArchConfig) -> f64 {
    stack_area(cfg).stack_total() * cfg.stacks as f64
}

/// Static + peripheral power in W (adders dominate; Table III: 15.86 W
/// per stack at AR×4/4k utilization).
pub fn peripheral_power_w(cfg: &ArchConfig) -> f64 {
    let ar = cfg.ar as f64;
    let w = cfg.adder_width as f64;
    let adders = 15.86 * (ar / 4.0) * (w / 4096.0);
    let ctrl = 0.12;
    (adders + ctrl) * cfg.stacks as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_anchor_arx4_4k() {
        let cfg = ArchConfig::new(4, 4096);
        let a = stack_area(&cfg);
        // Table III: DRAM total 148.33 mm² per stack.
        assert!((a.dram_total() - 148.33).abs() < 2.0, "{}", a.dram_total());
        assert!((a.hdl - 14.13).abs() < 0.1);
        assert!((a.adders_latches - 27.0).abs() < 4.0);
    }

    #[test]
    fn paper_design_space_extremes() {
        // §VI-B: AR×1-1k = 223.81 mm², AR×8-8k = 642.32 mm² (2 stacks).
        let small = total_area_mm2(&ArchConfig::new(1, 1024));
        let big = total_area_mm2(&ArchConfig::new(8, 8192));
        assert!(
            (200.0..260.0).contains(&small),
            "AR×1-1k area {small} vs paper 223.81"
        );
        assert!(
            (560.0..740.0).contains(&big),
            "AR×8-8k area {big} vs paper 642.32"
        );
    }

    #[test]
    fn area_monotone_in_ar_and_width() {
        let mut last = 0.0;
        for ar in [1u32, 2, 4, 8] {
            let a = total_area_mm2(&ArchConfig::new(ar, 4096));
            assert!(a > last);
            last = a;
        }
        let mut last = 0.0;
        for w in [1024u32, 2048, 4096, 8192] {
            let a = total_area_mm2(&ArchConfig::new(4, w));
            assert!(a > last);
            last = a;
        }
    }
}
