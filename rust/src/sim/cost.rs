//! Per-primitive cost models: NMU command streams (Table I) lowered to
//! cycles and energy on an [`ArchConfig`].
//!
//! The paper's in-house simulator is trace-driven at DRAM-command
//! granularity; at paper scale (2^16-coefficient polynomials × 30 limbs ×
//! millions of HE-ops) that is billions of commands, so — like the paper's
//! own evaluation — we lower each *polynomial-level* primitive to its
//! closed-form command counts (derived from the Table I costs and the
//! §IV data layout) and aggregate. `commands.rs` keeps the literal
//! command-level model; `cost_model_matches_command_sim` cross-checks the
//! two on small instances.

use super::config::ArchConfig;
use crate::mapping::layout::LayoutPlan;
use std::sync::Arc;

/// Cycle + energy pair, accumulated per breakdown category (Fig. 13).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    pub cycles: f64,
    pub energy_pj: f64,
}

impl Cost {
    pub fn new(cycles: f64, energy_pj: f64) -> Self {
        Self { cycles, energy_pj }
    }
    pub fn add(&mut self, o: Cost) {
        self.cycles += o.cycles;
        self.energy_pj += o.energy_pj;
    }
    pub fn scaled(self, f: f64) -> Cost {
        Cost::new(self.cycles * f, self.energy_pj * f)
    }
}

/// Fig. 13 breakdown categories.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub computation: Cost,
    pub permutation: Cost,
    pub read_write: Cost,
    pub interbank: Cost,
    pub channel: Cost,
    pub stack: Cost,
}

impl Breakdown {
    pub fn total(&self) -> Cost {
        let mut t = Cost::default();
        for c in [
            self.computation,
            self.permutation,
            self.read_write,
            self.interbank,
            self.channel,
            self.stack,
        ] {
            t.add(c);
        }
        t
    }

    pub fn add(&mut self, o: &Breakdown) {
        self.computation.add(o.computation);
        self.permutation.add(o.permutation);
        self.read_write.add(o.read_write);
        self.interbank.add(o.interbank);
        self.channel.add(o.channel);
        self.stack.add(o.stack);
    }

    pub fn scaled(&self, f: f64) -> Breakdown {
        Breakdown {
            computation: self.computation.scaled(f),
            permutation: self.permutation.scaled(f),
            read_write: self.read_write.scaled(f),
            interbank: self.interbank.scaled(f),
            channel: self.channel.scaled(f),
            stack: self.stack.scaled(f),
        }
    }

    /// Per-phase cycle counts in [`super::calib::PHASE_NAMES`] order —
    /// the attribution vector the calibration loop fits factors over.
    pub fn phase_cycles(&self) -> [f64; super::calib::PHASE_COUNT] {
        [
            self.computation.cycles,
            self.permutation.cycles,
            self.read_write.cycles,
            self.interbank.cycles,
            self.channel.cycles,
            self.stack.cycles,
        ]
    }
}

/// FHE parameter shape the cost model needs (decoupled from the
/// functional `CkksParams` so paper-scale settings cost without building
/// numerics).
#[derive(Debug, Clone, Copy)]
pub struct FheShape {
    pub log_n: usize,
    pub limbs: usize,
    pub k_special: usize,
    pub dnum: usize,
    /// Shift-add steps per (constant) modular multiplication: the modulus
    /// hamming weight h with Montgomery-friendly moduli, 64 without
    /// (paper §IV-B / Fig. 15 Base0).
    pub mult_shifts: u64,
}

impl FheShape {
    pub fn n(&self) -> usize {
        1 << self.log_n
    }

    pub fn paper_deep(montgomery: bool) -> Self {
        Self {
            log_n: 16,
            limbs: 24,
            k_special: 6,
            dnum: 4,
            mult_shifts: if montgomery { 3 } else { 64 },
        }
    }

    pub fn paper_lola(levels: usize) -> Self {
        Self {
            log_n: 14,
            limbs: levels,
            k_special: 1,
            dnum: 1,
            mult_shifts: 3,
        }
    }
}

/// The §IV-A data layout: one RNS polynomial spread over a subarray group
/// (16 subarrays = 16×16 mats). Derived from the same [`LayoutPlan`] the
/// executable hot path stores its tiles in, so the mat geometry the model
/// charges and the tile geometry the data actually has cannot drift.
pub struct Layout {
    pub coeffs_per_mat: u64,
    pub rows_per_poly_per_mat: u64,
    pub groups_per_bank: u64,
    pub total_groups: u64,
}

pub fn layout(cfg: &ArchConfig, shape: &FheShape) -> Layout {
    layout_from_plan(cfg, &LayoutPlan::get(shape.n()))
}

/// Mat-level geometry from the bank-tile plan: the plan's `n` spread
/// over a 16×16 mat group, tile rows packed into 512-bit mat rows.
pub fn layout_from_plan(cfg: &ArchConfig, plan: &LayoutPlan) -> Layout {
    let mats = 256u64; // 16×16 per group
    let coeffs_per_mat = (plan.n as u64 + mats - 1) / mats;
    let rows = (coeffs_per_mat * 64 + cfg.mat_row_bits() - 1) / cfg.mat_row_bits();
    let subarrays_per_group = 16u64;
    Layout {
        coeffs_per_mat,
        rows_per_poly_per_mat: rows,
        groups_per_bank: cfg.subarrays_per_bank() / subarrays_per_group,
        total_groups: cfg.total_subarrays() / subarrays_per_group,
    }
}

/// Cost model over one subarray group processing one RNS polynomial
/// (per-limb). Group-level costs scale across limbs/polys by the engine.
///
/// NTT/mul/keyswitch cycle counts are **derived from the
/// [`LayoutPlan`]** — the same object whose tiles the hot path computes
/// on: the four-step split fixes the stage partition (row pass intra-mat,
/// column pass inter-mat) and the plan's cross-tile stages fix the
/// inter-bank transpose traffic, replacing the previous hardcoded
/// stage-count arithmetic.
pub struct CostModel<'a> {
    pub cfg: &'a ArchConfig,
    pub shape: FheShape,
    pub lay: Layout,
    /// The bank-tile plan for this shape's ring (shared with the
    /// executable layers via the process-wide plan cache).
    pub plan: Arc<LayoutPlan>,
}

impl<'a> CostModel<'a> {
    pub fn new(cfg: &'a ArchConfig, shape: FheShape) -> Self {
        let plan = LayoutPlan::get(shape.n());
        let lay = layout_from_plan(cfg, &plan);
        Self {
            cfg,
            shape,
            lay,
            plan,
        }
    }

    /// Row-worth of NMU arithmetic (Fig. 5): activate two operand rows,
    /// stream M-value blocks through the adders, write back.
    fn row_op_cycles(&self, shifts: u64) -> f64 {
        let cfg = self.cfg;
        let vals = cfg.values_per_mat_row();
        let m = cfg.adders_per_subarray() / cfg.mats_per_subarray(); // adders per NMU
        let m = m.max(1);
        let blocks = (vals + m - 1) / m;
        let ld = cfg.mat_row_bits() / cfg.link_bits(); // row → latches
        let st = ld;
        (2 * cfg.act_pre_cycles() + ld + st + blocks * shifts) as f64
    }

    fn row_op_energy(&self, shifts: u64) -> f64 {
        let cfg = self.cfg;
        let vals = cfg.values_per_mat_row() * cfg.mats_per_subarray();
        let bits_moved = 2.0 * cfg.mat_row_bits() as f64 * cfg.mats_per_subarray() as f64;
        2.0 * cfg.e_row_act_pj()
            + bits_moved * cfg.e_pre_gsa_pj_per_bit()
            + vals as f64 * shifts as f64 * cfg.e_add64_pj()
    }

    /// Pointwise modular multiplication of one residue polynomial
    /// (vector of N coeffs across the group) — Montgomery: 2 constant
    /// mults of `mult_shifts` adds + the data mult of ~`3·h` effective
    /// adds (paper §IV-B: h additions instead of n).
    pub fn modmul_poly(&self) -> Breakdown {
        let rows = self.lay.rows_per_poly_per_mat;
        let shifts = 3 * self.shape.mult_shifts; // mult + 2 Montgomery consts
        let cycles = rows as f64 * self.row_op_cycles(shifts);
        let energy = rows as f64 * self.row_op_energy(shifts);
        Breakdown {
            computation: Cost::new(cycles, energy),
            ..Default::default()
        }
    }

    /// Pointwise modular addition of one residue polynomial.
    pub fn modadd_poly(&self) -> Breakdown {
        let rows = self.lay.rows_per_poly_per_mat;
        let cycles = rows as f64 * self.row_op_cycles(1);
        let energy = rows as f64 * self.row_op_energy(1);
        Breakdown {
            computation: Cost::new(cycles, energy),
            ..Default::default()
        }
    }

    /// One (i)NTT of one residue polynomial, costed from the
    /// [`LayoutPlan`]'s four-step split (§IV-C): the row pass
    /// (`plan.row_stages()`) is intra-mat; the column pass
    /// (`plan.column_stages()`) moves whole rows, and the
    /// `plan.cross_tile_stages()` of it that pair rows across bank tiles
    /// are inter-bank transposes over the segmented HDL/MDL links.
    pub fn ntt_poly(&self) -> Breakdown {
        let cfg = self.cfg;
        let plan = &self.plan;
        // Total butterfly stages = the plan's stage partition (row pass +
        // column pass = log2 N exactly; tested in mapping::layout).
        let total_stages = (plan.column_stages() + plan.row_stages()) as u64;

        // Compute: each stage does N/2 butterflies/group = one twiddle
        // mult + add/sub per pair → ~rows/2 row-ops of mult work + dynamic
        // twiddle update (one extra mult per stage, §IV-A3).
        let rows = self.lay.rows_per_poly_per_mat as f64;
        let shifts = 3 * self.shape.mult_shifts;
        let comp_per_stage = (rows / 2.0 + rows / 2.0) * self.row_op_cycles(shifts);
        let comp_energy_per_stage = rows * self.row_op_energy(shifts);
        let mut bd = Breakdown::default();
        bd.computation = Cost::new(
            comp_per_stage * total_stages as f64,
            comp_energy_per_stage * total_stages as f64,
        );

        // Permutation: the column pass moves half the rows each stage.
        // Cross-tile stages are inter-bank transfers over 16-bit HDL/MDL
        // segments; stage k has 2^k independent switch-isolated segments
        // (§III-B) — fewer segments ⇒ serialized transfers ⇒ the paper's
        // "slowest step drops bandwidth 16×". The remaining column
        // stages stay inside a tile (plain row moves, no serialization);
        // the row pass never moves data between mats.
        let row_xfer = cfg.mat_row_bits() / cfg.link_bits(); // 32 cycles
        let mut perm_cycles = 0.0;
        for k in 0..plan.cross_tile_stages() {
            let segments = 1u64 << k.min(4);
            let serial = (16 / segments).max(1);
            perm_cycles += (rows / 2.0) * (row_xfer * serial) as f64;
        }
        let in_tile_moves = (plan.column_stages() - plan.cross_tile_stages()) as f64;
        perm_cycles += in_tile_moves * (rows / 2.0) * row_xfer as f64;
        // Inter-bank transpose traffic straight off the plan, plus the
        // in-tile row moves at the same per-bit link energy.
        let bits_moved = plan.transpose_bits_moved() as f64
            + in_tile_moves * (self.shape.n() as f64 / 2.0) * 64.0;
        bd.permutation = Cost::new(perm_cycles, bits_moved * cfg.e_hdl_pj_per_bit() * 4.0);
        // Row activations for the moved data (whole column pass).
        let acts = plan.column_stages() as f64 * rows;
        bd.read_write = Cost::new(
            acts * cfg.act_pre_cycles() as f64,
            acts * cfg.e_row_act_pj() * cfg.mats_per_subarray() as f64,
        );
        bd
    }

    /// Automorphism of one residue polynomial (§IV-E): in-NMU permuted
    /// store (`nmu_pst`), one vertical and one horizontal inter-mat pass.
    pub fn automorphism_poly(&self) -> Breakdown {
        let cfg = self.cfg;
        let rows = self.lay.rows_per_poly_per_mat as f64;
        let row_xfer = (cfg.mat_row_bits() / cfg.link_bits()) as f64;
        // Step 1: per-row permutation via nmu_pst: 4 cycles per 64b value.
        let vals_per_row = cfg.values_per_mat_row() as f64;
        let pst = rows * vals_per_row * 4.0;
        // Steps 2–3: vertical then horizontal full-row moves.
        let moves = 2.0 * rows * row_xfer;
        let bits = 2.0 * self.shape.n() as f64 * 64.0;
        Breakdown {
            permutation: Cost::new(pst + moves, bits * cfg.e_hdl_pj_per_bit() * 4.0),
            read_write: Cost::new(
                2.0 * rows * cfg.act_pre_cycles() as f64,
                2.0 * rows * cfg.e_row_act_pj() * cfg.mats_per_subarray() as f64,
            ),
            ..Default::default()
        }
    }

    /// BConv from `l_in` to `l_out` residue polynomials (§IV-D): parallel
    /// partial products, MDL adder-tree intra-bank reduction, inter-bank
    /// all-to-all of partial products over the 256-bit chain network.
    pub fn bconv(&self, l_in: usize, l_out: usize, use_chain: bool) -> Breakdown {
        let cfg = self.cfg;
        let mut bd = Breakdown::default();
        // Partial products: l_in × l_out modmuls, parallel over groups —
        // engine folds parallelism; here cost is per-(in,out) pair chain:
        // one mult + tree-add depth log2(l_in).
        let mults = (l_in * l_out) as f64;
        let mm = self.modmul_poly();
        bd.computation = Cost::new(
            mm.computation.cycles * mults,
            mm.computation.energy_pj * mults,
        );
        let adds = (l_in as f64).log2().ceil() * l_out as f64;
        let ma = self.modadd_poly();
        bd.computation.add(Cost::new(
            ma.computation.cycles * adds,
            ma.computation.energy_pj * adds,
        ));
        // Inter-bank movement: every output needs partial products from
        // every bank holding an input limb: ~l_in·l_out poly transfers.
        // One polynomial = the plan's full tile set (banks × tile_elems
        // words), so the moved bits come straight from the layout.
        let poly_bits = (self.plan.banks * self.plan.tile_elems) as f64 * 64.0;
        let total_bits = poly_bits * mults;
        if use_chain {
            // Parallel chain: banks/2 links in a pseudo-channel carry
            // transfers concurrently (§III-C), each 256 b/cycle — vs the
            // single shared channel bus of the Base1 configuration.
            let links = (cfg.banks_per_pchannel() / 2) as f64;
            let cycles = total_bits / (cfg.interbank_bits() as f64 * links);
            bd.interbank = Cost::new(cycles, total_bits * cfg.e_chain_pj_per_bit());
        } else {
            // Base1: all transfers through the shared channel IO.
            let bytes = total_bits / 8.0;
            let ns = bytes / (cfg.channel_io_gbps() * 1e9) * 1e9;
            let cycles = ns / cfg.cycle_ns();
            bd.channel = Cost::new(cycles, total_bits * cfg.e_io_pj_per_bit());
        }
        bd
    }

    /// Generalized key switching (§II-A; the dominant primitive): per
    /// digit ModUp BConv + NTTs + inner products, then ModDown.
    pub fn keyswitch(&self, use_chain: bool) -> Breakdown {
        let l = self.shape.limbs;
        let k = self.shape.k_special;
        let dnum = self.shape.dnum.min(l).max(1);
        let alpha = (l + dnum - 1) / dnum;
        let mut bd = Breakdown::default();
        // iNTT the input (l limbs).
        let ntt = self.ntt_poly();
        bd.add(&ntt.scaled(l as f64));
        for _digit in 0..dnum {
            // ModUp: alpha → (l - alpha + k) BConv.
            bd.add(&self.bconv(alpha, l - alpha + k, use_chain));
            // NTT of the extended digit (l + k limbs).
            bd.add(&ntt.scaled((l + k) as f64));
            // Inner product with evk: 2 polys × (l+k) limbs mult + acc.
            let mm = self.modmul_poly();
            let ma = self.modadd_poly();
            bd.add(&mm.scaled(2.0 * (l + k) as f64));
            bd.add(&ma.scaled(2.0 * (l + k) as f64));
        }
        // ModDown: iNTT(k) + BConv(k → l) + sub/mult on l limbs, ×2 polys.
        bd.add(&ntt.scaled((2 * k) as f64));
        bd.add(&self.bconv(k, l, use_chain).scaled(2.0));
        let mm = self.modmul_poly();
        bd.add(&mm.scaled(2.0 * l as f64));
        // NTT back (2 polys × l limbs).
        bd.add(&ntt.scaled(2.0 * l as f64));
        bd
    }

    /// A **hoisted rotation group** (the program planner's rewrite of an
    /// N-rotation reduce tree): the input is iNTT'd and ModUp-BConv'd
    /// **once**, each of the `rotations` Galois elements then permutes
    /// the cached extended digits, NTTs them and inner-products with its
    /// own key, and one shared ModDown finishes the group — versus
    /// [`Self::keyswitch`] paying ModUp + ModDown per rotation. This is
    /// the cycle model behind the `hoisted_keyswitch_reduction_helr`
    /// bench figure.
    pub fn keyswitch_hoisted(&self, rotations: usize, use_chain: bool) -> Breakdown {
        let l = self.shape.limbs;
        let k = self.shape.k_special;
        let dnum = self.shape.dnum.min(l).max(1);
        let alpha = (l + dnum - 1) / dnum;
        let r = rotations.max(1) as f64;
        let mut bd = Breakdown::default();
        let ntt = self.ntt_poly();
        // Shared decompose: iNTT the input + per-digit ModUp BConv, once
        // for the whole group.
        bd.add(&ntt.scaled(l as f64));
        for _digit in 0..dnum {
            bd.add(&self.bconv(alpha, l - alpha + k, use_chain));
        }
        // Per rotation and per digit: automorphism of the cached extended
        // digit, NTT of the extended digit, gadget inner product.
        let auto = self.automorphism_poly();
        let mm = self.modmul_poly();
        let ma = self.modadd_poly();
        for _digit in 0..dnum {
            bd.add(&auto.scaled(r * (l + k) as f64));
            bd.add(&ntt.scaled(r * (l + k) as f64));
            bd.add(&mm.scaled(r * 2.0 * (l + k) as f64));
            bd.add(&ma.scaled(r * 2.0 * (l + k) as f64));
        }
        // One shared ModDown + NTT back (2 polys).
        bd.add(&ntt.scaled((2 * k) as f64));
        bd.add(&self.bconv(k, l, use_chain).scaled(2.0));
        bd.add(&mm.scaled(2.0 * l as f64));
        bd.add(&ntt.scaled(2.0 * l as f64));
        bd
    }

    /// A **hoisted-BSGS linear transform** (the compiled
    /// `LinearTransform` execution shape): the `babies` baby-step
    /// rotations form one hoisted group sharing a single
    /// decompose/ModUp + ModDown, while each of the `giants` giant-step
    /// rotations — applied to a fresh inner sum, not the shared input —
    /// pays a full [`Self::keyswitch`]. This is the cycle model behind
    /// the `bsgs_keyswitch_reduction_c2s` bench figure.
    pub fn keyswitch_bsgs(&self, babies: usize, giants: usize, use_chain: bool) -> Breakdown {
        let mut bd = Breakdown::default();
        if babies > 0 {
            bd.add(&self.keyswitch_hoisted(babies, use_chain));
        }
        if giants > 0 {
            bd.add(&self.keyswitch(use_chain).scaled(giants as f64));
        }
        bd
    }

    /// Key material loaded per key switch (evk digits), bytes — drives
    /// the load-save pipeline's data-loading term (§IV-F3).
    pub fn evk_bytes(&self) -> f64 {
        let l = self.shape.limbs;
        let k = self.shape.k_special;
        let dnum = self.shape.dnum.min(l).max(1);
        (2 * dnum * (l + k)) as f64 * self.shape.n() as f64 * 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(cfg: &ArchConfig) -> CostModel<'_> {
        CostModel::new(cfg, FheShape::paper_deep(true))
    }

    #[test]
    fn layout_matches_paper_section_iv_a() {
        // logN=16 over 16×16 mats: 256 coefficients per mat, 32 rows
        // of 512-bit holding 8×64b each (paper §IV-A1).
        let cfg = ArchConfig::default();
        let m = model(&cfg);
        assert_eq!(m.lay.coeffs_per_mat, 256);
        assert_eq!(m.lay.rows_per_poly_per_mat, 32);
    }

    #[test]
    fn ntt_cost_is_derived_from_the_layout_plan() {
        // The model's stage partition and transpose traffic must be the
        // plan's, not hardcoded: logN=16 → 8 column + 8 row stages, 4 of
        // the column stages crossing the 16 bank tiles.
        let cfg = ArchConfig::default();
        let m = model(&cfg);
        assert_eq!(m.plan.n, 1 << 16);
        assert_eq!(m.plan.column_stages() + m.plan.row_stages(), 16);
        assert_eq!(m.plan.cross_tile_stages(), 4);
        assert_eq!(
            m.plan.transpose_bits_moved(),
            4 * (1u64 << 15) * 64,
            "inter-bank transpose traffic off the plan"
        );
        let bd = m.ntt_poly();
        assert!(bd.computation.cycles > 0.0);
        assert!(bd.permutation.cycles > 0.0);
        // A ring with fewer cross-tile stages must charge less
        // permutation (same cfg, smaller N ⇒ fewer/cheaper transposes).
        let small = CostModel::new(&cfg, FheShape::paper_lola(4));
        assert!(small.ntt_poly().permutation.cycles < bd.permutation.cycles);
    }

    #[test]
    fn montgomery_moduli_speed_up_compute() {
        // Fig. 15(1): h-weight moduli vs 64-shift generic ⇒ faster.
        let cfg = ArchConfig::new(2, 2048);
        let fast = CostModel::new(&cfg, FheShape::paper_deep(true));
        let slow = CostModel::new(&cfg, FheShape::paper_deep(false));
        let f = fast.modmul_poly().computation.cycles;
        let s = slow.modmul_poly().computation.cycles;
        assert!(s > 1.5 * f, "montgomery {f} vs generic {s}");
    }

    #[test]
    fn interbank_chain_beats_channel_io() {
        // Fig. 15(2): the chain network reduces BConv movement latency
        // (paper: ~3.2× on movement).
        let cfg = ArchConfig::default();
        let m = model(&cfg);
        let with = m.bconv(6, 24, true);
        let without = m.bconv(6, 24, false);
        let t_with = with.interbank.cycles;
        let t_without = without.channel.cycles;
        assert!(
            t_without > 2.0 * t_with,
            "chain {t_with} vs channel {t_without}"
        );
    }

    #[test]
    fn keyswitch_dominated_by_ntt_and_movement() {
        let cfg = ArchConfig::default();
        let m = model(&cfg);
        let ks = m.keyswitch(true);
        let total = ks.total().cycles;
        assert!(total > 0.0);
        // sanity: all categories populated
        assert!(ks.computation.cycles > 0.0);
        assert!(ks.permutation.cycles > 0.0);
        assert!(ks.interbank.cycles > 0.0);
    }

    #[test]
    fn bsgs_keyswitch_cheaper_than_per_rotation() {
        // 3 babies + 2 giants hoisted vs 5 independent keyswitch
        // pipelines — the saving the CI-gated reduction figure pins.
        let cfg = ArchConfig::default();
        let m = model(&cfg);
        let hoisted = m.keyswitch_bsgs(3, 2, true).total().cycles;
        let per_rot = m.keyswitch(true).total().cycles * 5.0;
        assert!(
            hoisted < per_rot,
            "bsgs {hoisted} !< per-rotation {per_rot}"
        );
        // Degenerate shapes cost nothing extra.
        assert_eq!(m.keyswitch_bsgs(0, 0, true).total().cycles, 0.0);
    }

    #[test]
    fn higher_ar_lowers_primitive_latency() {
        let shape = FheShape::paper_deep(true);
        let mut last = f64::MAX;
        for ar in [1u32, 2, 4, 8] {
            let cfg = ArchConfig::new(ar, 4096);
            let m = CostModel::new(&cfg, shape);
            let c = m.ntt_poly().total().cycles;
            assert!(c < last, "AR{ar}: {c} !< {last}");
            last = c;
        }
    }

    #[test]
    fn wider_adders_lower_compute_latency() {
        let shape = FheShape::paper_deep(true);
        let mut last = f64::MAX;
        for w in [1024u32, 2048, 4096, 8192] {
            let cfg = ArchConfig::new(4, w);
            let m = CostModel::new(&cfg, shape);
            let c = m.modmul_poly().computation.cycles;
            assert!(c <= last);
            last = c;
        }
    }
}
