//! Table I: the FHEmem NMU command set, with per-command cycle costs and
//! a literal command-stream simulator used to cross-check the closed-form
//! cost model on small instances.

use super::config::ArchConfig;

/// One subarray-level NMU command (paper Table I / Fig. 7(b)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NmuCommand {
    /// Load `size` bits from SA column address to NMU latches.
    Ld { size_bits: u64 },
    /// Store from NMU latch to SA column address.
    St { size_bits: u64 },
    /// Horizontal inter-NMU move within a subarray.
    Hmov { size_bits: u64 },
    /// Vertical move between subarrays.
    Vmov { size_bits: u64 },
    /// Shift-add pass: `shifts` addition steps (h for Montgomery moduli).
    Add { shifts: u64 },
    /// Permuted store of per-NMU 64-bit latches (automorphism).
    Pst,
    /// Row activate + precharge (not in Table I; DRAM timing).
    ActPre,
}

impl NmuCommand {
    /// Execution cycles (Table I "Cycles" column).
    pub fn cycles(&self, cfg: &ArchConfig) -> u64 {
        match *self {
            NmuCommand::Ld { size_bits }
            | NmuCommand::St { size_bits }
            | NmuCommand::Hmov { size_bits }
            | NmuCommand::Vmov { size_bits } => size_bits / cfg.link_bits(),
            NmuCommand::Add { shifts } => shifts,
            NmuCommand::Pst => 4,
            NmuCommand::ActPre => cfg.act_pre_cycles(),
        }
    }

    /// Issue cost over the 16-bit command/address bus (§III-D: 2 cycles
    /// for 32-bit commands, 4 for 64-bit `nmu_pst`).
    pub fn issue_cycles(&self) -> u64 {
        match self {
            NmuCommand::Pst => 4,
            _ => 2,
        }
    }

    pub fn energy_pj(&self, cfg: &ArchConfig) -> f64 {
        match *self {
            NmuCommand::Ld { size_bits } | NmuCommand::St { size_bits } => {
                size_bits as f64 * cfg.e_pre_gsa_pj_per_bit()
            }
            NmuCommand::Hmov { size_bits } | NmuCommand::Vmov { size_bits } => {
                size_bits as f64 * cfg.e_hdl_pj_per_bit() * 4.0
            }
            NmuCommand::Add { shifts } => {
                shifts as f64 * cfg.e_add64_pj() * cfg.adders_per_subarray() as f64
            }
            NmuCommand::Pst => 64.0 * cfg.e_pre_gsa_pj_per_bit(),
            NmuCommand::ActPre => cfg.e_row_act_pj() * cfg.mats_per_subarray() as f64,
        }
    }
}

/// Literal command-stream execution: total (cycles, energy) including
/// issue overhead — the reference the closed-form model is checked
/// against.
pub fn run_stream(cfg: &ArchConfig, stream: &[NmuCommand]) -> (u64, f64) {
    let mut cycles = 0u64;
    let mut energy = 0.0f64;
    for cmd in stream {
        cycles += cmd.cycles(cfg).max(cmd.issue_cycles());
        energy += cmd.energy_pj(cfg);
    }
    (cycles, energy)
}

/// Build the command stream for one row-wise vector multiply (Fig. 5):
/// the stream behind `CostModel::row_op_cycles`.
pub fn vector_mult_stream(cfg: &ArchConfig, shifts: u64) -> Vec<NmuCommand> {
    let mut s = vec![
        NmuCommand::ActPre,
        NmuCommand::Ld {
            size_bits: cfg.mat_row_bits(),
        },
        NmuCommand::ActPre,
        NmuCommand::Ld {
            size_bits: cfg.mat_row_bits(),
        },
    ];
    let vals = cfg.values_per_mat_row();
    let m = (cfg.adders_per_subarray() / cfg.mats_per_subarray()).max(1);
    let blocks = (vals + m - 1) / m;
    for _ in 0..blocks {
        s.push(NmuCommand::Add { shifts });
    }
    s.push(NmuCommand::St {
        size_bits: cfg.mat_row_bits(),
    });
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::{CostModel, FheShape};

    #[test]
    fn table1_cycle_costs() {
        let cfg = ArchConfig::new(1, 1024);
        assert_eq!(
            NmuCommand::Ld { size_bits: 512 }.cycles(&cfg),
            32,
            "size/16 per Table I"
        );
        assert_eq!(NmuCommand::Hmov { size_bits: 256 }.cycles(&cfg), 16);
        assert_eq!(NmuCommand::Add { shifts: 7 }.cycles(&cfg), 7);
        assert_eq!(NmuCommand::Pst.cycles(&cfg), 4);
    }

    #[test]
    fn issue_costs_match_section_iii_d() {
        assert_eq!(NmuCommand::Pst.issue_cycles(), 4);
        assert_eq!(NmuCommand::Add { shifts: 64 }.issue_cycles(), 2);
    }

    #[test]
    fn cost_model_matches_command_sim() {
        // The closed-form row-op must track the literal stream within
        // issue-overhead slack on every configuration.
        for cfg in ArchConfig::design_space() {
            let shape = FheShape::paper_deep(true);
            let m = CostModel::new(&cfg, shape);
            let stream = vector_mult_stream(&cfg, 3 * shape.mult_shifts);
            let (stream_cycles, _) = run_stream(&cfg, &stream);
            let rows = m.lay.rows_per_poly_per_mat as f64;
            let closed = m.modmul_poly().computation.cycles / rows;
            let ratio = closed / stream_cycles as f64;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: closed {closed} vs stream {stream_cycles}",
                cfg.name()
            );
        }
    }

    #[test]
    fn stream_energy_positive_and_scales_with_shifts() {
        let cfg = ArchConfig::default();
        let (c3, e3) = run_stream(&cfg, &vector_mult_stream(&cfg, 3));
        let (c64, e64) = run_stream(&cfg, &vector_mult_stream(&cfg, 64));
        assert!(c64 > c3);
        assert!(e64 > e3);
    }
}
