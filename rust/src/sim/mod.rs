//! The FHEmem hardware model (paper §III, §V-A, Tables I–III):
//! configuration/geometry/timing/energy, the NMU command set, per-
//! primitive cost models, the area/power model, and the workload engine.

pub mod area;
pub mod calib;
pub mod commands;
pub mod config;
pub mod cost;
pub mod engine;

pub use calib::{Calibration, PHASE_COUNT, PHASE_NAMES};
pub use config::ArchConfig;
pub use cost::{Breakdown, Cost, CostModel, FheShape};
pub use engine::{simulate, SimOptions, SimResult};
