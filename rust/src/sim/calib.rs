//! Online cost-model calibration: per-phase scale factors fitted from
//! (simulated, measured) batch samples.
//!
//! The coordinator charges every batch on the analytical [`super::cost`]
//! model and also measures its wall-clock time. Each batch therefore
//! yields one equation: the measured nanoseconds should equal the sum of
//! the six [`super::Breakdown`] phases' simulated nanoseconds, each
//! scaled by an unknown per-phase factor. [`Calibration`] maintains an
//! exponentially decayed least-squares fit of those factors — the
//! normal equations `A·f = b` are EMA'd sample by sample and re-solved
//! with a ridge prior pulling unidentified directions toward `1.0` (a
//! phase the workload never exercises keeps its uncalibrated factor
//! instead of drifting on noise). No external deps: the 6×6 solve is a
//! hand-rolled Gaussian elimination.
//!
//! The fit is the feedback signal the ROADMAP's cost-model autotuner
//! searches with: a factor far from 1.0 names the phase whose constants
//! are wrong, and [`Calibration::residual`] says how much of the
//! measurement the calibrated model still cannot explain.
//!
//! Serialization goes through `util::json` so `--calibration <path>`
//! can persist the fit (factors *and* the decayed normal equations, so
//! a restarted server warm-starts instead of re-learning) across runs.

use crate::util::json::Json;
use std::path::Path;

/// Number of cost phases — the six [`super::Breakdown`] fields.
pub const PHASE_COUNT: usize = 6;

/// Phase names, in [`super::Breakdown`] field order (the same order
/// `Breakdown::phase_cycles` returns).
pub const PHASE_NAMES: [&str; PHASE_COUNT] = [
    "computation",
    "permutation",
    "read_write",
    "interbank",
    "channel",
    "stack",
];

/// Default per-sample EMA decay of the normal equations. At 0.97 the
/// effective window is ~33 batches — long enough to separate phases
/// across mixed batch shapes, short enough to track a workload shift.
pub const DEFAULT_DECAY: f64 = 0.97;

/// Default ridge strength (relative to the normal matrix trace) of the
/// pull-toward-1.0 prior.
pub const DEFAULT_RIDGE: f64 = 0.02;

/// EMA'd least-squares fit of per-phase cost-model scale factors.
#[derive(Debug, Clone)]
pub struct Calibration {
    decay: f64,
    ridge: f64,
    /// EMA'd normal matrix Σ λ^k · p·pᵀ (p = per-phase simulated ns).
    a: [[f64; PHASE_COUNT]; PHASE_COUNT],
    /// EMA'd Σ λ^k · p·w (w = measured wall ns).
    b: [f64; PHASE_COUNT],
    factors: [f64; PHASE_COUNT],
    samples: u64,
    /// EMA of the relative squared residual (w − f·p)² / w².
    resid_ema: f64,
    /// Per-phase simulated ns observed this run (not persisted).
    seen_phase_ns: [f64; PHASE_COUNT],
    /// Measured wall ns observed this run (not persisted).
    seen_wall_ns: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Self::new(DEFAULT_DECAY, DEFAULT_RIDGE)
    }
}

impl Calibration {
    pub fn new(decay: f64, ridge: f64) -> Self {
        assert!((0.0..1.0).contains(&decay), "decay must be in [0, 1)");
        assert!(ridge >= 0.0, "ridge must be non-negative");
        Self {
            decay,
            ridge,
            a: [[0.0; PHASE_COUNT]; PHASE_COUNT],
            b: [0.0; PHASE_COUNT],
            factors: [1.0; PHASE_COUNT],
            samples: 0,
            resid_ema: 0.0,
            seen_phase_ns: [0.0; PHASE_COUNT],
            seen_wall_ns: 0.0,
        }
    }

    /// Current per-phase scale factors, in [`PHASE_NAMES`] order.
    pub fn factors(&self) -> &[f64; PHASE_COUNT] {
        &self.factors
    }

    /// Samples folded into the fit so far (including persisted history).
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// EMA'd relative RMS residual of the calibrated prediction —
    /// `0.0` means the calibrated model explains the measurements
    /// exactly, `1.0` means it is off by as much as the measurement.
    pub fn residual(&self) -> f64 {
        self.resid_ema.sqrt()
    }

    /// Calibrated prediction for one sample: Σ factor_j · phase_ns_j.
    pub fn predict_ns(&self, phase_ns: &[f64; PHASE_COUNT]) -> f64 {
        self.factors
            .iter()
            .zip(phase_ns)
            .map(|(f, p)| f * p)
            .sum()
    }

    /// Fold one (per-phase simulated ns, measured wall ns) batch sample
    /// into the fit and re-solve the factors.
    pub fn observe(&mut self, phase_ns: &[f64; PHASE_COUNT], wall_ns: f64) {
        if wall_ns <= 0.0 || phase_ns.iter().all(|&p| p <= 0.0) {
            return;
        }
        for j in 0..PHASE_COUNT {
            self.b[j] = self.decay * self.b[j] + phase_ns[j] * wall_ns;
            for k in 0..PHASE_COUNT {
                self.a[j][k] = self.decay * self.a[j][k] + phase_ns[j] * phase_ns[k];
            }
        }
        self.samples += 1;
        self.refit();
        // Residual of the *updated* factors on this sample.
        let err = (wall_ns - self.predict_ns(phase_ns)) / wall_ns;
        self.resid_ema = self.decay * self.resid_ema + (1.0 - self.decay) * err * err;
        for j in 0..PHASE_COUNT {
            self.seen_phase_ns[j] += phase_ns[j];
        }
        self.seen_wall_ns += wall_ns;
    }

    /// Calibrated drift over everything observed **this run**: current
    /// factors applied to the accumulated per-phase simulated ns, over
    /// the accumulated measured ns. `None` before the first sample. The
    /// uncalibrated counterpart of this ratio is the scheduler's
    /// `cost_model_drift_ratio`; calibration's job is to move this one
    /// toward 1.0.
    pub fn aggregate_ratio(&self) -> Option<f64> {
        if self.seen_wall_ns <= 0.0 {
            return None;
        }
        Some(self.predict_ns(&self.seen_phase_ns) / self.seen_wall_ns)
    }

    /// Uncalibrated drift over the same observed samples (all factors
    /// pinned at 1.0) — the like-for-like baseline for
    /// [`Self::aggregate_ratio`].
    pub fn uncalibrated_ratio(&self) -> Option<f64> {
        if self.seen_wall_ns <= 0.0 {
            return None;
        }
        Some(self.seen_phase_ns.iter().sum::<f64>() / self.seen_wall_ns)
    }

    /// Re-solve `(A + μI)·f = b + μ·1` — ridge-regularized normal
    /// equations with the prior `f = 1`. μ scales with `trace(A)/6` so
    /// the prior strength is invariant to the workload's magnitude.
    fn refit(&mut self) {
        let trace: f64 = (0..PHASE_COUNT).map(|j| self.a[j][j]).sum();
        if trace <= 0.0 {
            return;
        }
        let mu = self.ridge * trace / PHASE_COUNT as f64 + f64::MIN_POSITIVE;
        let mut m = [[0.0f64; PHASE_COUNT + 1]; PHASE_COUNT];
        for j in 0..PHASE_COUNT {
            for k in 0..PHASE_COUNT {
                m[j][k] = self.a[j][k];
            }
            m[j][j] += mu;
            m[j][PHASE_COUNT] = self.b[j] + mu;
        }
        if let Some(f) = solve(&mut m) {
            // Physical sanity: a phase cannot run backwards, and a
            // transiently wild fit must not poison the drift gauges.
            for j in 0..PHASE_COUNT {
                self.factors[j] = f[j].clamp(0.05, 20.0);
            }
        }
    }

    /// Serialize the fit (config, factors, decayed normal equations).
    pub fn to_json(&self) -> Json {
        let row = |r: &[f64]| Json::Array(r.iter().map(|&v| Json::Float(v)).collect());
        Json::obj([
            ("version", Json::Num(1)),
            ("decay", Json::Float(self.decay)),
            ("ridge", Json::Float(self.ridge)),
            ("samples", Json::Num(self.samples)),
            ("residual", Json::Float(self.residual())),
            (
                "phases",
                Json::Array(
                    PHASE_NAMES
                        .iter()
                        .map(|&n| Json::Str(n.to_string()))
                        .collect(),
                ),
            ),
            ("factors", row(&self.factors)),
            ("normal_b", row(&self.b)),
            (
                "normal_a",
                Json::Array(self.a.iter().map(|r| row(r)).collect()),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<Self, String> {
        let vec6 = |j: &Json, what: &str| -> Result<[f64; PHASE_COUNT], String> {
            let arr = j.as_array().map_err(|e| format!("{what}: {e}"))?;
            if arr.len() != PHASE_COUNT {
                return Err(format!("{what}: expected {PHASE_COUNT} entries, got {}", arr.len()));
            }
            let mut out = [0.0; PHASE_COUNT];
            for (i, v) in arr.iter().enumerate() {
                out[i] = v.as_f64().map_err(|e| format!("{what}[{i}]: {e}"))?;
            }
            Ok(out)
        };
        let decay = doc.field("decay")?.as_f64()?;
        let ridge = doc.field("ridge")?.as_f64()?;
        if !(0.0..1.0).contains(&decay) || ridge < 0.0 {
            return Err(format!("bad calibration config: decay {decay}, ridge {ridge}"));
        }
        let mut cal = Self::new(decay, ridge);
        cal.samples = doc.field("samples")?.as_u64()?;
        cal.factors = vec6(doc.field("factors")?, "factors")?;
        cal.b = vec6(doc.field("normal_b")?, "normal_b")?;
        let rows = doc.field("normal_a")?.as_array()?;
        if rows.len() != PHASE_COUNT {
            return Err(format!("normal_a: expected {PHASE_COUNT} rows, got {}", rows.len()));
        }
        for (j, r) in rows.iter().enumerate() {
            cal.a[j] = vec6(r, "normal_a row")?;
        }
        for f in cal.factors {
            if !f.is_finite() || !(0.05..=20.0).contains(&f) {
                return Err(format!("factor {f} outside sane range"));
            }
        }
        Ok(cal)
    }

    /// Load a persisted fit; `None` (fresh calibration) if the file does
    /// not exist or does not parse — a corrupt file must not take the
    /// server down.
    pub fn load(path: &Path) -> Option<Self> {
        let text = std::fs::read_to_string(path).ok()?;
        let doc = Json::parse(&text).ok()?;
        Self::from_json(&doc).ok()
    }

    /// Persist the fit (pretty JSON, atomic enough for a single writer).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().write_pretty())
    }
}

/// Solve the 6×7 augmented system in place by Gaussian elimination with
/// partial pivoting. Returns `None` only on a numerically singular
/// pivot, which the ridge term rules out for observed data.
fn solve(m: &mut [[f64; PHASE_COUNT + 1]; PHASE_COUNT]) -> Option<[f64; PHASE_COUNT]> {
    for col in 0..PHASE_COUNT {
        let pivot = (col..PHASE_COUNT)
            .max_by(|&a, &b| m[a][col].abs().total_cmp(&m[b][col].abs()))
            .unwrap();
        if m[pivot][col].abs() < 1e-300 {
            return None;
        }
        m.swap(col, pivot);
        for row in col + 1..PHASE_COUNT {
            let ratio = m[row][col] / m[col][col];
            for k in col..=PHASE_COUNT {
                m[row][k] -= ratio * m[col][k];
            }
        }
    }
    let mut f = [0.0f64; PHASE_COUNT];
    for col in (0..PHASE_COUNT).rev() {
        let mut acc = m[col][PHASE_COUNT];
        for k in col + 1..PHASE_COUNT {
            acc -= m[col][k] * f[k];
        }
        f[col] = acc / m[col][col];
    }
    if f.iter().all(|v| v.is_finite()) {
        Some(f)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::SplitMix64;

    /// Random positive phase mix with per-sample shape variation — the
    /// diversity that makes the six factors identifiable.
    fn sample_mix(rng: &mut SplitMix64) -> [f64; PHASE_COUNT] {
        let mut p = [0.0; PHASE_COUNT];
        for slot in p.iter_mut() {
            *slot = 1e4 + rng.f64() * 1e6;
        }
        p
    }

    #[test]
    fn converges_to_planted_per_phase_skew() {
        let planted = [1.6, 0.5, 2.2, 1.0, 0.7, 1.3];
        let mut cal = Calibration::default();
        let mut rng = SplitMix64::new(0xCA11B);
        for _ in 0..400 {
            let p = sample_mix(&mut rng);
            let w: f64 = planted.iter().zip(&p).map(|(f, x)| f * x).sum();
            cal.observe(&p, w);
        }
        for (j, (&got, &want)) in cal.factors().iter().zip(&planted).enumerate() {
            let rel = (got - want).abs() / want;
            assert!(
                rel < 0.15,
                "phase {} ({}) did not converge: got {got:.3}, planted {want:.3}",
                j,
                PHASE_NAMES[j]
            );
        }
        assert!(cal.residual() < 0.10, "residual too high: {}", cal.residual());
        // The calibrated aggregate ratio must sit essentially at 1.0
        // while the uncalibrated one carries the planted skew.
        let cal_ratio = cal.aggregate_ratio().unwrap();
        let unc_ratio = cal.uncalibrated_ratio().unwrap();
        assert!((cal_ratio - 1.0).abs() < 0.05, "calibrated ratio {cal_ratio}");
        assert!((cal_ratio - 1.0).abs() < (unc_ratio - 1.0).abs());
    }

    #[test]
    fn unexercised_phases_hold_the_prior() {
        // Samples that only ever exercise phase 0: the fit must scale
        // phase 0 and leave the unidentified phases at 1.0 (the ridge
        // prior), not drift them on noise.
        let mut cal = Calibration::default();
        let mut rng = SplitMix64::new(7);
        for _ in 0..200 {
            let mut p = [0.0; PHASE_COUNT];
            p[0] = 1e5 + rng.f64() * 1e5;
            cal.observe(&p, 3.0 * p[0]);
        }
        assert!((cal.factors()[0] - 3.0).abs() < 0.2, "got {}", cal.factors()[0]);
        for j in 1..PHASE_COUNT {
            assert!(
                (cal.factors()[j] - 1.0).abs() < 1e-6,
                "unexercised phase {j} drifted to {}",
                cal.factors()[j]
            );
        }
    }

    #[test]
    fn collinear_samples_still_drive_ratio_to_one() {
        // A serving workload where every batch has the same phase mix:
        // the six factors are not identifiable, but the fitted
        // combination must still predict the wall time — the calibrated
        // drift ratio goes to 1.0 even without identifiability.
        let mix = [5e5, 3e5, 2e5, 1e5, 5e4, 2e4];
        let mut cal = Calibration::default();
        for _ in 0..100 {
            cal.observe(&mix, 0.25 * mix.iter().sum::<f64>());
        }
        let ratio = cal.aggregate_ratio().unwrap();
        assert!((ratio - 1.0).abs() < 0.05, "collinear ratio {ratio}");
        let unc = cal.uncalibrated_ratio().unwrap();
        assert!((unc - 4.0).abs() < 0.2, "uncalibrated should stay ~4: {unc}");
    }

    #[test]
    fn json_roundtrip_preserves_the_fit() {
        let mut cal = Calibration::default();
        let mut rng = SplitMix64::new(99);
        let planted = [0.8, 1.4, 1.0, 2.0, 0.6, 1.1];
        for _ in 0..50 {
            let p = sample_mix(&mut rng);
            let w: f64 = planted.iter().zip(&p).map(|(f, x)| f * x).sum();
            cal.observe(&p, w);
        }
        let text = cal.to_json().write_pretty();
        let back = Calibration::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.samples(), cal.samples());
        for j in 0..PHASE_COUNT {
            assert!(
                (back.factors()[j] - cal.factors()[j]).abs() < 1e-9,
                "factor {j} changed across roundtrip"
            );
        }
        // A restored fit keeps learning from where it left off.
        let mut warm = back.clone();
        let p = sample_mix(&mut rng);
        warm.observe(&p, planted.iter().zip(&p).map(|(f, x)| f * x).sum());
        assert_eq!(warm.samples(), cal.samples() + 1);
    }

    #[test]
    fn rejects_corrupt_payloads() {
        assert!(Calibration::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut cal = Calibration::default();
        cal.observe(&[1e5; PHASE_COUNT], 6e5);
        let mut doc = cal.to_json().write();
        doc = doc.replace("\"decay\": 0.97", "\"decay\": 1.5");
        assert!(Calibration::from_json(&Json::parse(&doc).unwrap()).is_err());
    }

    #[test]
    fn degenerate_samples_are_ignored() {
        let mut cal = Calibration::default();
        cal.observe(&[0.0; PHASE_COUNT], 100.0);
        cal.observe(&[1e5; PHASE_COUNT], 0.0);
        assert_eq!(cal.samples(), 0);
        assert!(cal.aggregate_ratio().is_none());
        assert_eq!(cal.factors(), &[1.0; PHASE_COUNT]);
    }
}
