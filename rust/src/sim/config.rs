//! FHEmem architectural configuration (paper Table II + §V-A).
//!
//! Geometry: 2× 8-high HBM2E stacks (16 GB each), 32 pseudo-channels per
//! stack, 8 banks per pseudo-channel, 64 MB banks built from 512×512-cell
//! mats, 16 mats per subarray. The **aspect ratio** (AR) divides the mat
//! rows: AR×k has 512/k rows per mat and k× as many subarrays per bank
//! (128 at AR×1 → 1024 at AR×8), trading area for latency/energy/
//! parallelism (§II-D1). The **adder width** is the total adder bits per
//! subarray (1k–8k; e.g. 4k = 16 NMUs × 4 64-bit adders).

/// One FHEmem hardware configuration point (the Fig. 12 design space).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchConfig {
    /// Aspect-ratio multiplier: 1, 2, 4 or 8.
    pub ar: u32,
    /// Adder bits per subarray: 1024, 2048, 4096 or 8192.
    pub adder_width: u32,
    /// Number of HBM stacks (paper: 2 → 32 GB).
    pub stacks: u32,
}

impl ArchConfig {
    pub fn new(ar: u32, adder_width: u32) -> Self {
        assert!([1, 2, 4, 8].contains(&ar), "AR must be 1/2/4/8");
        assert!(
            [1024, 2048, 4096, 8192].contains(&adder_width),
            "adder width must be 1k/2k/4k/8k"
        );
        Self {
            ar,
            adder_width,
            stacks: 2,
        }
    }

    /// Short name like "ARx4-4k" (paper Fig. 12 labels).
    pub fn name(&self) -> String {
        format!("ARx{}-{}k", self.ar, self.adder_width / 1024)
    }

    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_lowercase();
        let (ar_s, w_s) = s.strip_prefix("arx")?.split_once('-')?;
        let ar: u32 = ar_s.parse().ok()?;
        let w: u32 = w_s.strip_suffix('k')?.parse::<u32>().ok()? * 1024;
        Some(Self::new(ar, w))
    }

    /// The nine points explored in Fig. 12 (AR×{1,2,4,8} × matched widths).
    pub fn design_space() -> Vec<ArchConfig> {
        let mut v = Vec::new();
        for ar in [1u32, 2, 4, 8] {
            for w in [1024u32, 2048, 4096, 8192] {
                v.push(Self::new(ar, w));
            }
        }
        v
    }

    // ----------------------------------------------------------------
    // Geometry (Table II)
    // ----------------------------------------------------------------

    pub fn banks_per_pchannel(&self) -> u64 {
        8
    }

    pub fn pchannels_per_stack(&self) -> u64 {
        32
    }

    pub fn banks_per_stack(&self) -> u64 {
        self.banks_per_pchannel() * self.pchannels_per_stack()
    }

    pub fn total_banks(&self) -> u64 {
        self.banks_per_stack() * self.stacks as u64
    }

    /// Mats per subarray (a subarray row spans 16 mats → 1 kB row).
    pub fn mats_per_subarray(&self) -> u64 {
        16
    }

    /// Mat row size in bits (512 cells per mat row).
    pub fn mat_row_bits(&self) -> u64 {
        512
    }

    /// Rows per mat after AR division (512 at AR×1 → 64 at AR×8).
    pub fn rows_per_mat(&self) -> u64 {
        512 / self.ar as u64
    }

    /// Subarrays per bank: 128·AR (64 MB bank of 512×512-cell mats).
    pub fn subarrays_per_bank(&self) -> u64 {
        128 * self.ar as u64
    }

    pub fn total_subarrays(&self) -> u64 {
        self.subarrays_per_bank() * self.total_banks()
    }

    /// 64-bit adders per subarray.
    pub fn adders_per_subarray(&self) -> u64 {
        (self.adder_width / 64) as u64
    }

    /// Total 64-bit adders in the system (paper §VI-A3: ARx4-4k → 16M).
    pub fn total_adders(&self) -> u64 {
        self.adders_per_subarray() * self.total_subarrays()
    }

    /// Values (64-bit words) per mat row.
    pub fn values_per_mat_row(&self) -> u64 {
        self.mat_row_bits() / 64
    }

    // ----------------------------------------------------------------
    // Timing (Table II; AR scaling per §II-D1 / [28])
    // ----------------------------------------------------------------

    /// Logic/transfer clock (paper §VI-A3: 500 MHz additions).
    pub fn clock_ghz(&self) -> f64 {
        0.5
    }

    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.clock_ghz()
    }

    /// Activation+restore latency in ns. ARx4 (128 rows) has half the
    /// cycle of ARx1 (512 rows) [10][28]; interpolate with a √-like
    /// decay anchored at those two points.
    pub fn t_ras_ns(&self) -> f64 {
        let base = 29.0;
        base * Self::ar_latency_factor(self.ar)
    }

    pub fn t_rp_ns(&self) -> f64 {
        16.0 * Self::ar_latency_factor(self.ar)
    }

    pub fn t_rrd_ns(&self) -> f64 {
        2.0
    }

    fn ar_latency_factor(ar: u32) -> f64 {
        // anchors: AR×1 → 1.0, AR×4 → 0.5 (paper quote), AR×2/AR×8
        // interpolated/extrapolated geometrically (×~0.7 per AR doubling).
        match ar {
            1 => 1.0,
            2 => 0.71,
            4 => 0.5,
            8 => 0.36,
            _ => unreachable!(),
        }
    }

    /// Row activate+precharge round trip in logic cycles.
    pub fn act_pre_cycles(&self) -> u64 {
        ((self.t_ras_ns() + self.t_rp_ns()) / self.cycle_ns()).ceil() as u64
    }

    // ----------------------------------------------------------------
    // Energy (Table II, 10 nm, AR×1 anchors; AR scaling per §II-D1)
    // ----------------------------------------------------------------

    /// Row activation energy in pJ.
    pub fn e_row_act_pj(&self) -> f64 {
        413.0 * Self::ar_energy_factor(self.ar)
    }

    fn ar_energy_factor(ar: u32) -> f64 {
        // AR×4 consumes 33% less activation energy than AR×1 (§II-D1).
        match ar {
            1 => 1.0,
            2 => 0.82,
            4 => 0.67,
            8 => 0.55,
            _ => unreachable!(),
        }
    }

    /// Pre-GSA (local, intra-mat/subarray) data movement energy, pJ/bit.
    pub fn e_pre_gsa_pj_per_bit(&self) -> f64 {
        0.69
    }

    /// Post-GSA (bank-level) data movement energy, pJ/bit.
    pub fn e_post_gsa_pj_per_bit(&self) -> f64 {
        0.53
    }

    /// Channel IO energy, pJ/bit.
    pub fn e_io_pj_per_bit(&self) -> f64 {
        0.77
    }

    /// 64-bit full-adder energy per add step, pJ (synthesized NMU logic,
    /// 10 nm — calibrated so ARx4-4k multiplication energy sits slightly
    /// above the 4.1 pJ/op ASIC multipliers of CraterLake, §II-D1).
    pub fn e_add64_pj(&self) -> f64 {
        0.35
    }

    /// Horizontal data-link energy, pJ/bit (Table III: 5.3 fJ/b avg ×
    /// wire-length factor ≈ global DL class).
    pub fn e_hdl_pj_per_bit(&self) -> f64 {
        0.0053
    }

    /// Inter-bank chain link energy, pJ/bit (Table III: 0.53 pJ/b).
    pub fn e_chain_pj_per_bit(&self) -> f64 {
        0.53
    }

    // ----------------------------------------------------------------
    // Interconnect widths (§III-B/C, §V-A)
    // ----------------------------------------------------------------

    /// MDL/HDL link width per mat column / subarray (16-bit).
    pub fn link_bits(&self) -> u64 {
        16
    }

    /// Inter-bank chain width (256-bit).
    pub fn interbank_bits(&self) -> u64 {
        256
    }

    /// Channel IO width (pseudo-channel, 64-bit @ DDR — effective GB/s).
    pub fn channel_io_gbps(&self) -> f64 {
        // HBM2E: 3.2 Gb/s/pin × 64 pins / 8 = 25.6 GB/s per pseudo-channel
        25.6
    }

    /// Intra-stack crossbar bisection bandwidth (GB/s, §V-A).
    pub fn stack_bisection_gbps(&self) -> f64 {
        64.0
    }

    /// Stack-to-stack bandwidth (GB/s, §V-A).
    pub fn interstack_gbps(&self) -> f64 {
        256.0
    }

    // ----------------------------------------------------------------
    // Derived headline metrics (§VI-A3 anchors, used as tests)
    // ----------------------------------------------------------------

    /// Effective 64-bit multiplication throughput in TB/s, accounting for
    /// row activations, operand transfer and shift-add serialization
    /// (paper: ARx4-4k ≈ 637.61 TB/s).
    pub fn effective_mult_tbps(&self, shifts_per_mult: u64) -> f64 {
        let adders = self.total_adders() as f64;
        // Per multiplication: `shifts` add cycles; operand movement and
        // activations amortized over a full row of values per mat.
        let vals = self.values_per_mat_row() * self.mats_per_subarray(); // per subarray row
        let m = self.adders_per_subarray();
        let blocks = (vals + m - 1) / m;
        let ld_st = 2 * (self.mat_row_bits() / self.link_bits()); // operand in + result out
        let total_cycles = self.act_pre_cycles() * 2
            + blocks * shifts_per_mult
            + 2 * ld_st;
        let mults = vals as f64;
        let mult_per_cycle_per_subarray = mults / total_cycles as f64;
        let bytes = mult_per_cycle_per_subarray * 8.0 * self.total_subarrays() as f64;
        bytes * self.clock_ghz() * 1e9 / 1e12 * adders / adders // TB/s
    }

    /// Peak internal NTT bandwidth in TB/s (paper: 2048 TB/s at ARx4,
    /// 32 GB, half the subarrays transferring via 256-bit links).
    pub fn peak_ntt_internal_tbps(&self) -> f64 {
        let active = self.total_subarrays() as f64 / 2.0;
        let bits_per_cycle = self.link_bits() as f64 * self.mats_per_subarray() as f64;
        active * bits_per_cycle / 8.0 * self.clock_ghz() * 1e9 / 1e12
    }

    /// Total memory capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.stacks as u64 * 16 * (1 << 30)
    }
}

impl Default for ArchConfig {
    /// The paper's lowest-EDAP configuration (ARx4-4k).
    fn default() -> Self {
        Self::new(4, 4096)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_table2() {
        let c = ArchConfig::new(1, 1024);
        assert_eq!(c.subarrays_per_bank(), 128);
        assert_eq!(c.total_banks(), 512);
        assert_eq!(c.capacity_bytes(), 32 << 30);
        let c8 = ArchConfig::new(8, 8192);
        assert_eq!(c8.subarrays_per_bank(), 1024);
        assert_eq!(c8.rows_per_mat(), 64);
    }

    #[test]
    fn arx4_4k_has_16m_adders() {
        // §VI-A3: "ARx4-4k FHEmem has 16 million 64-bit adders".
        let c = ArchConfig::new(4, 4096);
        let adders = c.total_adders();
        assert!(
            (15_000_000..18_000_000).contains(&adders),
            "adders = {adders}"
        );
    }

    #[test]
    fn effective_mult_throughput_near_paper() {
        // §VI-A3: ARx4-4k effective 64-bit mult throughput ≈ 637.61 TB/s
        // (with Montgomery-friendly shifts ≈ 3 rather than full 64).
        let c = ArchConfig::new(4, 4096);
        let t = c.effective_mult_tbps(3);
        assert!(
            (300.0..1100.0).contains(&t),
            "effective mult throughput {t} TB/s far from paper's 637"
        );
    }

    #[test]
    fn peak_ntt_bandwidth_near_paper() {
        // §VI-A3: 2048 TB/s peak internal NTT bandwidth at ARx4 / 32 GB.
        let c = ArchConfig::new(4, 4096);
        let bw = c.peak_ntt_internal_tbps();
        assert!(
            (1000.0..3000.0).contains(&bw),
            "peak NTT bw {bw} TB/s far from paper's 2048"
        );
    }

    #[test]
    fn ar_scaling_monotone() {
        let mut last_t = f64::MAX;
        let mut last_e = f64::MAX;
        for ar in [1u32, 2, 4, 8] {
            let c = ArchConfig::new(ar, 4096);
            assert!(c.t_ras_ns() < last_t);
            assert!(c.e_row_act_pj() < last_e);
            last_t = c.t_ras_ns();
            last_e = c.e_row_act_pj();
        }
    }

    #[test]
    fn name_parse_roundtrip() {
        for c in ArchConfig::design_space() {
            assert_eq!(ArchConfig::parse(&c.name()), Some(c));
        }
    }
}
