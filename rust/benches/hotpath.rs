//! Hot-path microbenches (§Perf): the Rust CKKS primitives, the batched
//! bank-pool execution engine, and the simulator engine itself.
//!
//! The headline measurement is the batched limb-parallel NTT at N = 8192
//! (the axis FHEmem assigns to banks): serial vs bank-pool at 1/2/4/8
//! threads, with a bit-identity cross-check between the serial and
//! parallel paths. `--json PATH` writes the records to a JSON file
//! (see BENCH_hotpath.json at the repo root for the tracked baseline):
//!
//! ```sh
//! cargo bench --bench hotpath -- --json BENCH_hotpath.json
//! ```

use fhemem::ckks::{CkksContext, Evaluator, KeyChain};
use fhemem::math::ntt::{naive_forward, naive_inverse, NttContext};
use fhemem::math::primes::ntt_primes;
use fhemem::parallel::BankPool;
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::bench::bench_fn;
use fhemem::util::check::SplitMix64;
use fhemem::util::cli::Args;
use std::sync::Arc;

struct Record {
    name: String,
    threads: usize,
    median_ns: f64,
    speedup_vs_serial: f64,
}

/// Batched limb-parallel NTT at N=8192: batch × limbs independent rows,
/// forward+inverse per iteration (roundtrip keeps the buffer valid).
fn bench_batched_ntt(records: &mut Vec<Record>) -> bool {
    let logn = 13usize;
    let n = 1usize << logn;
    let limbs = 8usize;
    let batch = 8usize;
    let tables: Vec<Arc<NttContext>> = ntt_primes(40, n, limbs)
        .iter()
        .map(|m| NttContext::get(m.q, n))
        .collect();
    let mut rng = SplitMix64::new(1);
    let rows: Vec<Vec<u64>> = (0..batch * limbs)
        .map(|r| {
            let q = tables[r % limbs].q;
            (0..n).map(|_| rng.below(q)).collect()
        })
        .collect();

    // Bit-identity: the parallel path must reproduce the serial path.
    let serial_out = {
        let mut buf = rows.clone();
        for (r, row) in buf.iter_mut().enumerate() {
            tables[r % limbs].forward(row);
        }
        buf
    };
    let par_out = {
        let mut buf = rows.clone();
        BankPool::new(0).par_rows(&mut buf, |r, row: &mut Vec<u64>| {
            tables[r % limbs].forward(row)
        });
        buf
    };
    let bit_identical = serial_out == par_out;
    println!(
        "parallel-vs-serial NTT outputs bit-identical: {}",
        if bit_identical { "yes" } else { "NO — BUG" }
    );

    let machine = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut serial_ns = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = BankPool::new(threads);
        let mut buf = rows.clone();
        let name =
            format!("ntt fwd+inv batch={batch} limbs={limbs} n=2^{logn} threads={threads}");
        let s = bench_fn(&name, || {
            pool.par_rows(&mut buf, |r, row: &mut Vec<u64>| {
                let t = &tables[r % limbs];
                t.forward(row);
                t.inverse(row);
            });
        });
        let median_ns = s.median_ns();
        if threads == 1 {
            serial_ns = median_ns;
        }
        let speedup = if median_ns > 0.0 { serial_ns / median_ns } else { 0.0 };
        println!("    -> {speedup:.2}x vs serial ({machine} hw threads available)");
        records.push(Record {
            name,
            threads,
            median_ns,
            speedup_vs_serial: speedup,
        });
    }
    bit_identical
}

fn bench_batched_ckks(records: &mut Vec<Record>) {
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 1));
    let ev = Evaluator::new(ctx.clone(), chain, 2);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 97) as f64).collect();
    let batch = 8usize;
    let a: Vec<_> = (0..batch).map(|_| ev.encrypt_real(&z, ctx.l())).collect();
    let b: Vec<_> = (0..batch).map(|_| ev.encrypt_real(&z, ctx.l())).collect();
    let _ = ev.mul(&a[0], &b[0]); // warm the key cache
    let pool_threads = fhemem::parallel::pool().threads();
    let name = format!("ckks_hmul_batch={batch} logN=12 L=8 threads={pool_threads}");
    let s = bench_fn(&name, || {
        std::hint::black_box(ev.mul_batch(&a, &b));
    });
    records.push(Record {
        name,
        threads: pool_threads,
        median_ns: s.median_ns(),
        speedup_vs_serial: 0.0,
    });
}

/// Naive (per-call root regeneration + full-width reductions) vs the
/// precomputed Shoup/Harvey engine, single row at N = 8192. The returned
/// speedup is the acceptance number the CI gate checks (> 1.0 required).
fn bench_ntt_engine_vs_naive(records: &mut Vec<Record>) -> f64 {
    let logn = 13usize;
    let n = 1usize << logn;
    let q = ntt_primes(50, n, 1)[0].q;
    let ctx = NttContext::get(q, n);
    let mut rng = SplitMix64::new(7);
    let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();

    // Bit-identity first: the engine must reproduce the naive kernels.
    let engine_out = {
        let mut buf = data.clone();
        ctx.forward(&mut buf);
        buf
    };
    let naive_out = {
        let mut buf = data.clone();
        naive_forward(&mut buf, q);
        buf
    };
    assert_eq!(engine_out, naive_out, "engine diverged from naive kernel");

    let mut buf = data.clone();
    let s_naive = bench_fn(&format!("ntt naive fwd+inv n=2^{logn}"), || {
        naive_forward(&mut buf, q);
        naive_inverse(&mut buf, q);
        std::hint::black_box(&buf);
    });
    let mut buf = data.clone();
    let s_pre = bench_fn(&format!("ntt precomputed fwd+inv n=2^{logn}"), || {
        ctx.forward(&mut buf);
        ctx.inverse(&mut buf);
        std::hint::black_box(&buf);
    });
    let speedup = if s_pre.median_ns() > 0.0 {
        s_naive.median_ns() / s_pre.median_ns()
    } else {
        0.0
    };
    println!("    -> precomputed NTT {speedup:.2}x vs naive at N={n}");
    records.push(Record {
        name: format!("ntt precomputed-vs-naive n=2^{logn} (speedup field = vs naive)"),
        threads: 1,
        median_ns: s_pre.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

fn write_json(path: &str, records: &[Record], bit_identical: bool, ntt_speedup: f64) {
    let machine = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"hotpath\",\n");
    s.push_str(&format!("  \"machine_threads\": {machine},\n"));
    s.push_str(&format!("  \"parallel_bit_identical_to_serial\": {bit_identical},\n"));
    s.push_str(&format!(
        "  \"ntt_precomputed_speedup_vs_naive_n8192\": {ntt_speedup:.3},\n"
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"threads\": {}, \"median_ns\": {:.1}, \
             \"speedup_vs_serial\": {:.3}}}{}\n",
            r.name,
            r.threads,
            r.median_ns,
            r.speedup_vs_serial,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    fhemem::parallel::configure_threads(args.threads());
    let mut records = Vec::new();

    // L3 substrate: single-row NTT at artifact and functional sizes.
    for logn in [11usize, 13] {
        let n = 1 << logn;
        let q = ntt_primes(40, n, 1)[0].q;
        let t = NttContext::get(q, n);
        let mut rng = SplitMix64::new(5);
        let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        let mut buf = data.clone();
        let s = bench_fn(&format!("ntt_forward n=2^{logn}"), || {
            buf.copy_from_slice(&data);
            t.forward(&mut buf);
            std::hint::black_box(&buf);
        });
        let butterflies = (n / 2 * logn) as f64;
        println!("    -> {:.1} M butterflies/s", butterflies / s.median.as_secs_f64() / 1e6);
    }

    // The NTT engine: precomputed Shoup/Harvey context vs the naive
    // (regenerate-roots, full-reduction) baseline. CI fails if ≤ 1x.
    let ntt_speedup = bench_ntt_engine_vs_naive(&mut records);

    // The bank-pool engine: batched limb-parallel NTT (acceptance: ≥2x
    // at N=8192 with ≥4 threads) + batched CKKS HMul.
    let bit_identical = bench_batched_ntt(&mut records);
    bench_batched_ckks(&mut records);

    // CKKS ops at func_default (logN=12, L=8, dnum=4).
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 1));
    let ev = Evaluator::new(ctx.clone(), chain, 2);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 97) as f64).collect();
    let a = ev.encrypt_real(&z, ctx.l());
    let b = ev.encrypt_real(&z, ctx.l());
    // warm the key cache so the bench measures the op, not keygen
    let _ = ev.mul(&a, &b);
    let _ = ev.rotate(&a, 1);
    bench_fn("ckks_hadd logN=12 L=8", || {
        std::hint::black_box(ev.add(&a, &b));
    });
    bench_fn("ckks_hmul(+KS+rescale) logN=12 L=8", || {
        std::hint::black_box(ev.mul(&a, &b));
    });
    bench_fn("ckks_rotate logN=12 L=8", || {
        std::hint::black_box(ev.rotate(&a, 1));
    });

    // Simulator engine throughput.
    bench_fn("sim_engine full resnet20 trace", || {
        std::hint::black_box(simulate(
            &ArchConfig::default(),
            &workloads::resnet20(),
            SimOptions::default(),
        ));
    });

    if let Some(path) = args.get("json") {
        write_json(path, &records, bit_identical, ntt_speedup);
    }
}
