//! Hot-path microbenches (§Perf): the Rust CKKS primitives, the batched
//! bank-pool execution engine, and the simulator engine itself.
//!
//! The headline measurement is the batched limb-parallel NTT at N = 8192
//! (the axis FHEmem assigns to banks): serial vs bank-pool at 1/2/4/8
//! threads, with a bit-identity cross-check between the serial and
//! parallel paths. `--json PATH` writes the records to a JSON file
//! (see BENCH_hotpath.json at the repo root for the tracked baseline):
//!
//! ```sh
//! cargo bench --bench hotpath -- --json BENCH_hotpath.json
//! ```

use fhemem::ckks::linear::eval_chebyshev;
use fhemem::ckks::{Ciphertext, CkksContext, CtRepr, Evaluator, KeyChain};
use fhemem::coordinator::Coordinator;
use fhemem::mapping::LayoutPlan;
use fhemem::math::ntt::{naive_forward, naive_inverse, NttContext};
use fhemem::math::primes::ntt_primes;
use fhemem::parallel::BankPool;
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::bench::bench_fn;
use fhemem::util::check::SplitMix64;
use fhemem::util::cli::Args;
use fhemem::util::json::Json;
use std::sync::Arc;

struct Record {
    name: String,
    threads: usize,
    median_ns: f64,
    speedup_vs_serial: f64,
}

/// Batched limb-parallel NTT at N=8192: batch × limbs independent rows,
/// forward+inverse per iteration (roundtrip keeps the buffer valid).
fn bench_batched_ntt(records: &mut Vec<Record>) -> bool {
    let logn = 13usize;
    let n = 1usize << logn;
    let limbs = 8usize;
    let batch = 8usize;
    let tables: Vec<Arc<NttContext>> = ntt_primes(40, n, limbs)
        .iter()
        .map(|m| NttContext::get(m.q, n))
        .collect();
    let mut rng = SplitMix64::new(1);
    let rows: Vec<Vec<u64>> = (0..batch * limbs)
        .map(|r| {
            let q = tables[r % limbs].q;
            (0..n).map(|_| rng.below(q)).collect()
        })
        .collect();

    // Bit-identity: the parallel path must reproduce the serial path.
    let serial_out = {
        let mut buf = rows.clone();
        for (r, row) in buf.iter_mut().enumerate() {
            tables[r % limbs].forward(row);
        }
        buf
    };
    let par_out = {
        let mut buf = rows.clone();
        BankPool::new(0).par_rows(&mut buf, |r, row: &mut Vec<u64>| {
            tables[r % limbs].forward(row)
        });
        buf
    };
    let bit_identical = serial_out == par_out;
    println!(
        "parallel-vs-serial NTT outputs bit-identical: {}",
        if bit_identical { "yes" } else { "NO — BUG" }
    );

    let machine = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut serial_ns = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = BankPool::new(threads);
        let mut buf = rows.clone();
        let name =
            format!("ntt fwd+inv batch={batch} limbs={limbs} n=2^{logn} threads={threads}");
        let s = bench_fn(&name, || {
            pool.par_rows(&mut buf, |r, row: &mut Vec<u64>| {
                let t = &tables[r % limbs];
                t.forward(row);
                t.inverse(row);
            });
        });
        let median_ns = s.median_ns();
        if threads == 1 {
            serial_ns = median_ns;
        }
        let speedup = if median_ns > 0.0 { serial_ns / median_ns } else { 0.0 };
        println!("    -> {speedup:.2}x vs serial ({machine} hw threads available)");
        records.push(Record {
            name,
            threads,
            median_ns,
            speedup_vs_serial: speedup,
        });
    }
    bit_identical
}

fn bench_batched_ckks(records: &mut Vec<Record>) {
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 1));
    let ev = Evaluator::new(ctx.clone(), chain, 2);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 97) as f64).collect();
    let batch = 8usize;
    let a: Vec<_> = (0..batch).map(|_| ev.encrypt_real(&z, ctx.l())).collect();
    let b: Vec<_> = (0..batch).map(|_| ev.encrypt_real(&z, ctx.l())).collect();
    let _ = ev.mul(&a[0], &b[0]); // warm the key cache
    let pool_threads = fhemem::parallel::pool().threads();
    let name = format!("ckks_hmul_batch={batch} logN=12 L=8 threads={pool_threads}");
    let s = bench_fn(&name, || {
        std::hint::black_box(ev.mul_batch(&a, &b));
    });
    records.push(Record {
        name,
        threads: pool_threads,
        median_ns: s.median_ns(),
        speedup_vs_serial: 0.0,
    });
}

/// Naive (per-call root regeneration + full-width reductions) vs the
/// precomputed Shoup/Harvey engine, single row at N = 8192. The returned
/// speedup is the acceptance number the CI gate checks (> 1.0 required).
fn bench_ntt_engine_vs_naive(records: &mut Vec<Record>) -> f64 {
    let logn = 13usize;
    let n = 1usize << logn;
    let q = ntt_primes(50, n, 1)[0].q;
    let ctx = NttContext::get(q, n);
    let mut rng = SplitMix64::new(7);
    let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();

    // Bit-identity first: the engine must reproduce the naive kernels.
    let engine_out = {
        let mut buf = data.clone();
        ctx.forward(&mut buf);
        buf
    };
    let naive_out = {
        let mut buf = data.clone();
        naive_forward(&mut buf, q);
        buf
    };
    assert_eq!(engine_out, naive_out, "engine diverged from naive kernel");

    let mut buf = data.clone();
    let s_naive = bench_fn(&format!("ntt naive fwd+inv n=2^{logn}"), || {
        naive_forward(&mut buf, q);
        naive_inverse(&mut buf, q);
        std::hint::black_box(&buf);
    });
    let mut buf = data.clone();
    let s_pre = bench_fn(&format!("ntt precomputed fwd+inv n=2^{logn}"), || {
        ctx.forward(&mut buf);
        ctx.inverse(&mut buf);
        std::hint::black_box(&buf);
    });
    let speedup = if s_pre.median_ns() > 0.0 {
        s_naive.median_ns() / s_pre.median_ns()
    } else {
        0.0
    };
    println!("    -> precomputed NTT {speedup:.2}x vs naive at N={n}");
    records.push(Record {
        name: format!("ntt precomputed-vs-naive n=2^{logn} (speedup field = vs naive)"),
        threads: 1,
        median_ns: s_pre.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

/// Four-step (bank-tiled) vs radix-2 NTT at N = 2^15, single row — the
/// paper-scale transform the ROADMAP's four-step item targeted. The
/// returned speedup is CI-gated (> 1.0 required): the tiled schedule's
/// one-pass-per-row cache behaviour must actually beat the radix-2
/// kernel's log N full-array sweeps at this size.
fn bench_fourstep_vs_radix2(records: &mut Vec<Record>) -> f64 {
    let logn = 15usize;
    let n = 1usize << logn;
    let q = ntt_primes(50, n, 1)[0].q;
    let ctx = NttContext::get(q, n);
    let plan = LayoutPlan::get(n);
    let mut rng = SplitMix64::new(11);
    let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();

    // Bit-identity first: the tiled four-step must reproduce radix-2.
    let mut radix_out = data.clone();
    ctx.forward(&mut radix_out);
    let mut tiles: Vec<Vec<u64>> = data.chunks(plan.tile_elems).map(|c| c.to_vec()).collect();
    ctx.forward_tiled(&mut tiles, &plan);
    let glued: Vec<u64> = tiles.iter().flatten().copied().collect();
    assert_eq!(glued, radix_out, "four-step diverged from radix-2");

    let mut buf = data.clone();
    let s_radix = bench_fn(&format!("ntt radix2 fwd+inv n=2^{logn}"), || {
        ctx.forward(&mut buf);
        ctx.inverse(&mut buf);
        std::hint::black_box(&buf);
    });
    let mut tiles: Vec<Vec<u64>> = data.chunks(plan.tile_elems).map(|c| c.to_vec()).collect();
    let s_four = bench_fn(
        &format!(
            "ntt fourstep tiled fwd+inv n=2^{logn} (n1={} banks={})",
            plan.n1, plan.banks
        ),
        || {
            ctx.forward_tiled(&mut tiles, &plan);
            ctx.inverse_tiled(&mut tiles, &plan);
            std::hint::black_box(&tiles);
        },
    );
    let speedup = if s_four.median_ns() > 0.0 {
        s_radix.median_ns() / s_four.median_ns()
    } else {
        0.0
    };
    println!("    -> four-step NTT {speedup:.2}x vs radix-2 at N={n}");
    records.push(Record {
        name: format!("ntt fourstep-vs-radix2 n=2^{logn} (speedup field = vs radix-2)"),
        threads: 1,
        median_ns: s_four.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

/// Tiled vs flat HMul at logN=15 (func_wide): the whole multiplicative
/// hot path — tensor, fused cross term, 3-digit key switch, rescale —
/// on bank tiles (four-step NTTs throughout) against the flat radix-2
/// evaluator. Operands are pre-tiled, mirroring the serving path that
/// converts once at the batch edge. Recorded as
/// `tiled_hmul_speedup_vs_flat_n32768` in the JSON artifact.
fn bench_tiled_hmul_vs_flat(records: &mut Vec<Record>) -> f64 {
    let ctx = CkksContext::new(CkksParams::func_wide());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 3));
    let ev = Evaluator::new(ctx.clone(), chain, 4);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 89) as f64).collect();
    let a = ev.encrypt_real(&z, ctx.l());
    let b = ev.encrypt_real(&z, ctx.l());
    // Warm the key cache and cross-check bit-identity before timing.
    let flat_out = ev.mul(&a, &b);
    let (at, bt) = (a.to_tiled(), b.to_tiled());
    let tiled_out = at.mul(&ev, &bt);
    assert_eq!(
        tiled_out.to_flat().c0.data, flat_out.c0.data,
        "tiled HMul diverged from flat"
    );

    let s_flat = bench_fn("ckks_hmul flat logN=15 L=3", || {
        std::hint::black_box(ev.mul(&a, &b));
    });
    let s_tiled = bench_fn("ckks_hmul tiled logN=15 L=3", || {
        std::hint::black_box(at.mul(&ev, &bt));
    });
    let speedup = if s_tiled.median_ns() > 0.0 {
        s_flat.median_ns() / s_tiled.median_ns()
    } else {
        0.0
    };
    println!("    -> tiled HMul {speedup:.2}x vs flat at logN=15");
    records.push(Record {
        name: "ckks_hmul tiled-vs-flat logN=15 (speedup field = vs flat)".to_string(),
        threads: fhemem::parallel::pool().threads(),
        median_ns: s_tiled.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

/// Deferred-correction vs eager-correction op chain at N = 2^15: an
/// HMul-shaped tensor (fused cross term + add/sub), then INTT → rescale
/// → automorphism. The lazy variant carries `Bound::Lazy2q` between ops
/// and folds once at the chain exit (inside the transform); the eager
/// variant normalizes after every op. Bit-identity is asserted first;
/// the speedup is recorded as `lazy_chain_speedup_n32768` and CI-gated
/// (> 1.0 required — eager does strictly more memory passes).
fn bench_lazy_chain(records: &mut Vec<Record>) -> f64 {
    use fhemem::math::poly::{Domain, RnsPoly};
    use fhemem::math::tiled::TiledRnsPoly;
    let ctx = CkksContext::new(CkksParams::func_wide());
    let limbs = ctx.l();
    let mut rng = SplitMix64::new(0x1A2);
    let mut mk = |domain| {
        let mut p = RnsPoly::zero(ctx.basis.clone(), limbs, domain);
        for j in 0..limbs {
            let q = ctx.basis.q(j);
            for c in p.data[j].iter_mut() {
                *c = rng.below(q);
            }
        }
        TiledRnsPoly::from_flat(&p)
    };
    let a = mk(Domain::Ntt);
    let b = mk(Domain::Ntt);
    let c = mk(Domain::Ntt);
    let k = RnsPoly::rotation_to_galois(1, ctx.n());

    let lazy_chain = || {
        let mut t = TiledRnsPoly::fused_mul_add(&[(&a, &b), (&c, &a)]);
        t.add_assign(&b);
        t.sub_assign(&c);
        t.to_coeff(); // single fold, inside the inverse transform
        let r = t.rescale_by_last();
        r.automorphism(k)
    };
    let eager_chain = || {
        let mut t = TiledRnsPoly::fused_mul_add(&[(&a, &b), (&c, &a)]);
        t.normalize();
        t.add_assign(&b);
        t.normalize();
        t.sub_assign(&c);
        t.normalize();
        t.to_coeff();
        let r = t.rescale_by_last();
        r.automorphism(k)
    };
    assert_eq!(
        lazy_chain().to_flat().data,
        eager_chain().to_flat().data,
        "lazy chain diverged from eager"
    );

    let s_eager = bench_fn("op chain eager (normalize per op) n=2^15", || {
        std::hint::black_box(eager_chain());
    });
    let s_lazy = bench_fn("op chain deferred correction n=2^15", || {
        std::hint::black_box(lazy_chain());
    });
    let speedup = if s_lazy.median_ns() > 0.0 {
        s_eager.median_ns() / s_lazy.median_ns()
    } else {
        0.0
    };
    println!("    -> deferred-correction chain {speedup:.2}x vs eager at N=2^15");
    records.push(Record {
        name: "op chain lazy-vs-eager n=2^15 (speedup field = vs eager)".to_string(),
        threads: fhemem::parallel::pool().threads(),
        median_ns: s_lazy.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

/// Batched HMul through the generic `CtRepr` fan-out at logN=15: a
/// pre-tiled batch (the serving path — one conversion per batch edge)
/// vs the flat batch. Recorded as
/// `tiled_batch_hmul_speedup_vs_flat_batch_n32768`; CI requires the key
/// to be present.
fn bench_tiled_batch_hmul_vs_flat_batch(records: &mut Vec<Record>) -> f64 {
    let ctx = CkksContext::new(CkksParams::func_wide());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 5));
    let ev = Evaluator::new(ctx.clone(), chain, 6);
    let slots = ctx.encoder.slots();
    let batch = 4usize;
    let mk = |seed: usize| {
        let z: Vec<f64> = (0..slots).map(|i| 0.001 * ((i + seed) % 83) as f64).collect();
        ev.encrypt_real(&z, ctx.l())
    };
    let fa: Vec<Ciphertext> = (0..batch).map(|i| mk(i)).collect();
    let fb: Vec<Ciphertext> = (batch..2 * batch).map(|i| mk(i)).collect();
    let ta: Vec<_> = fa.iter().map(|ct| ct.to_tiled()).collect();
    let tb: Vec<_> = fb.iter().map(|ct| ct.to_tiled()).collect();

    // Warm the key cache and cross-check bit-identity before timing.
    let flat_out = ev.mul_batch(&fa, &fb);
    let tiled_out = ev.mul_batch(&ta, &tb);
    for (i, (t, f)) in tiled_out.iter().zip(&flat_out).enumerate() {
        assert_eq!(
            t.to_flat().c0.data, f.c0.data,
            "tiled batch HMul [{i}] diverged from flat batch"
        );
    }

    let s_flat = bench_fn("ckks_hmul_batch flat logN=15 batch=4", || {
        std::hint::black_box(ev.mul_batch(&fa, &fb));
    });
    let s_tiled = bench_fn("ckks_hmul_batch tiled logN=15 batch=4", || {
        std::hint::black_box(ev.mul_batch(&ta, &tb));
    });
    let speedup = if s_tiled.median_ns() > 0.0 {
        s_flat.median_ns() / s_tiled.median_ns()
    } else {
        0.0
    };
    println!("    -> tiled batch HMul {speedup:.2}x vs flat batch at logN=15");
    records.push(Record {
        name: "ckks_hmul_batch tiled-vs-flat logN=15 batch=4 (speedup field = vs flat)"
            .to_string(),
        threads: fhemem::parallel::pool().threads(),
        median_ns: s_tiled.median_ns(),
        speedup_vs_serial: speedup,
    });
    speedup
}

/// One HELR iteration, hand-written vs `fhemem-compile`: the compiled
/// path goes Builder graph → CSE + rotation hoisting + auto-rescale →
/// tiled mixed-batch execution on the coordinator. Returns
/// `(compiled_helr_speedup_vs_handwritten, hoisted_keyswitch_reduction_helr)`;
/// CI requires the first to be present and gates the second > 1.0 (the
/// planner must strictly reduce keyswitch pipelines on the HELR graph).
fn bench_compiled_helr(records: &mut Vec<Record>) -> (f64, f64) {
    use fhemem::program::{compile, Builder, PassOptions};
    use std::collections::HashMap;
    let coord = Coordinator::new(CkksParams::func_tiny(), ArchConfig::default(), None);
    let ctx = CkksContext::new(CkksParams::func_tiny());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 0xBE7C));
    let ev = Arc::new(Evaluator::new(ctx.clone(), chain, 0xBE7D));
    let slots = ctx.encoder.slots();
    let features = 16usize;
    let x: Vec<f64> = (0..slots).map(|i| 0.05 * ((i % 9) as f64 - 4.0)).collect();
    let y: Vec<f64> = (0..slots).map(|i| ((i / features) % 2) as f64).collect();
    let sigmoid = vec![0.5, 0.25]; // degree-1 fit fits func_tiny's levels
    let level = ctx.l();
    let w: Vec<f64> = (0..slots).map(|i| 0.02 * ((i % 7) as f64 - 3.0)).collect();
    let cw = ev.encrypt_real(&w, level);

    let prog = {
        let mut b = Builder::new();
        let win = b.input("w");
        let xw = b.mul_plain(win, x.clone());
        let dot = b.rotate_sum(xw, features);
        let pred = b.chebyshev(dot, sigmoid.clone());
        let err = b.sub_plain_vec(pred, y.clone());
        let grad = b.mul_plain(err, x.clone());
        b.output("grad", grad);
        b.build().expect("HELR graph")
    };
    let meta = HashMap::from([("w".to_string(), (level, ctx.scale()))]);
    let compiled = compile(&prog, &ctx, &meta, &PassOptions::default()).expect("compile");
    let unhoisted = compile(
        &prog,
        &ctx,
        &meta,
        &PassOptions {
            hoist_rotations: false,
            ..PassOptions::default()
        },
    )
    .expect("compile unhoisted");
    let reduction = unhoisted.counts.keyswitch_invocations as f64
        / compiled.counts.keyswitch_invocations.max(1) as f64;
    println!(
        "    -> HELR keyswitch pipelines: {} unhoisted vs {} hoisted ({reduction:.1}x reduction)",
        unhoisted.counts.keyswitch_invocations, compiled.counts.keyswitch_invocations
    );

    // Bit-identity first (and key-cache warm-up for both paths).
    let handwritten = |cw: &Ciphertext| {
        let xw = ev.mul_plain(cw, &x);
        let dot = ev.rotate_sum_hoisted(&xw, features);
        let pred = eval_chebyshev(&ev, &dot, &sigmoid);
        let err = ev.sub_plain(&pred, &y);
        ev.mul_plain(&err, &x)
    };
    let want = handwritten(&cw);
    let inputs = HashMap::from([("w".to_string(), cw.clone())]);
    let run = compiled.execute(&coord, &ev, &inputs).expect("compiled run");
    assert_eq!(
        run.outputs[0].1.c0.data, want.c0.data,
        "compiled HELR diverged from hand-written"
    );

    let s_hand = bench_fn("helr iteration hand-written (func_tiny)", || {
        std::hint::black_box(handwritten(&cw));
    });
    let s_comp = bench_fn("helr iteration compiled program (func_tiny)", || {
        std::hint::black_box(compiled.execute(&coord, &ev, &inputs).expect("compiled run"));
    });
    let speedup = if s_comp.median_ns() > 0.0 {
        s_hand.median_ns() / s_comp.median_ns()
    } else {
        0.0
    };
    println!("    -> compiled HELR {speedup:.2}x vs hand-written");
    records.push(Record {
        name: "helr compiled-vs-handwritten func_tiny (speedup field = vs handwritten)"
            .to_string(),
        threads: fhemem::parallel::pool().threads(),
        median_ns: s_comp.median_ns(),
        speedup_vs_serial: speedup,
    });
    (speedup, reduction)
}

/// Bootstrapping as a compiled program: the real CoeffToSlot transform's
/// BSGS plan on func_boot gives the CI-gated keyswitch-pipeline
/// reduction (`bsgs_keyswitch_reduction_c2s`, > 1.0 required), and the
/// compiled program's op shape — two BSGS transforms plus the EvalMod
/// keyswitches and pointwise work — is costed statically on the
/// paper-scale n=2^15 ring (`bootstrap_cycles_n32768`). Building the
/// n=2^15 numerics is out of bench budget; the shape-level model is the
/// same one the coordinator charges at run time.
fn bench_compiled_bootstrap(records: &mut Vec<Record>) -> (f64, f64) {
    use fhemem::ckks::bootstrap::BootstrapConfig;
    use fhemem::program::{compile, PassOptions};
    use fhemem::sim::{Breakdown, CostModel, FheShape};
    use std::collections::HashMap;

    let ctx = CkksContext::new(CkksParams::func_boot());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 0xB007));
    let ev = Evaluator::new(ctx.clone(), chain, 0xB008);
    let bs = BootstrapConfig::default().build(&ev);
    let prog = bs.to_program();
    let meta = HashMap::from([("raised".to_string(), (ctx.l(), ctx.scale()))]);
    let s = bench_fn("bootstrap program compile+plan (func_boot)", || {
        std::hint::black_box(
            compile(&prog, &ctx, &meta, &PassOptions::default()).expect("bootstrap compiles"),
        );
    });
    let compiled =
        compile(&prog, &ctx, &meta, &PassOptions::default()).expect("bootstrap compiles");

    // CoeffToSlot (transform 0): keyswitch pipelines unhoisted vs
    // hoisted — the baby steps collapse into one shared decompose.
    let c2s = &compiled.lt_plans[0].plan;
    let reduction = c2s.keyswitches(false) as f64 / c2s.keyswitches(true).max(1) as f64;
    println!(
        "    -> CoeffToSlot BSGS (n1={}): {} keyswitch pipelines unhoisted vs {} hoisted \
         ({reduction:.1}x reduction)",
        c2s.n1,
        c2s.keyswitches(false),
        c2s.keyswitches(true)
    );

    // Static paper-scale costing: func_boot's RNS shape on the 2^15 ring.
    let cfg = ArchConfig::default();
    let shape = FheShape {
        log_n: 15,
        limbs: 14,
        k_special: 3,
        dnum: 7,
        mult_shifts: 3,
    };
    let m = CostModel::new(&cfg, shape);
    let limbs = shape.limbs as f64;
    let mut bd = Breakdown::default();
    for lp in &compiled.lt_plans {
        let (b, g) = (lp.plan.baby_rots.len(), lp.plan.giant_rots.len());
        bd.add(&m.keyswitch_bsgs(b, g, true));
        bd.add(&m.automorphism_poly().scaled(2.0 * limbs * (b + g) as f64));
    }
    let lt_ks: usize = compiled.lt_plans.iter().map(|p| p.keyswitches()).sum();
    let other_ks = compiled.counts.keyswitch_invocations.saturating_sub(lt_ks);
    bd.add(&m.keyswitch(true).scaled(other_ks as f64));
    let pointwise = (compiled.counts.pmuls + compiled.counts.rescales) as f64;
    bd.add(&m.modmul_poly().scaled(limbs * pointwise));
    bd.add(&m.modadd_poly().scaled(2.0 * limbs * compiled.counts.adds as f64));
    let bootstrap_cycles = bd.total().cycles;
    println!(
        "    -> bootstrap @ n=2^15: {:.3e} sim cycles ({} keyswitch pipelines, {} rotations)",
        bootstrap_cycles, compiled.counts.keyswitch_invocations, compiled.counts.rotations
    );

    records.push(Record {
        name: "bootstrap compile+plan func_boot (speedup field = c2s keyswitch reduction)"
            .to_string(),
        threads: 1,
        median_ns: s.median_ns(),
        speedup_vs_serial: reduction,
    });
    (reduction, bootstrap_cycles)
}

/// The serving layer end to end (minus TCP): two tenants' ops flow
/// through keystore lookup + the admission-controlled batching scheduler
/// + mixed-batch bank-pool execution. The returned ops/s figure is the
/// `service_batch_throughput_ops_per_s` key the CI smoke job requires in
/// the JSON artifact.
fn bench_service_throughput(records: &mut Vec<Record>) -> f64 {
    use fhemem::service::{FheService, SchedulerConfig, WireOp};
    use std::time::{Duration, Instant};
    // max_batch == feeder count: each blocking feeder keeps exactly one
    // op in flight, so every flush is count-triggered — the figure
    // measures execution, not the max_delay timer.
    let svc = FheService::new(
        ArchConfig::default(),
        SchedulerConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(2),
            max_queue: 256,
            max_tenant_inflight: 0,
        },
    );
    svc.register(1, CkksParams::func_tiny(), 0xA11CE).unwrap();
    svc.register(2, CkksParams::func_tiny(), 0xB0B).unwrap();
    let total_ops = 64usize;
    let feeders = 4usize;
    // Encrypt outside the timed region: the figure measures serving, not
    // client-side encryption.
    let inputs: Vec<(u64, Ciphertext, Ciphertext)> = (0..total_ops)
        .map(|i| {
            let tid = 1 + (i % 2) as u64;
            let t = svc.store.get(tid).unwrap();
            let slots = t.ctx.encoder.slots();
            let z: Vec<f64> = (0..slots).map(|j| 0.001 * ((i + j) % 31) as f64).collect();
            (tid, t.eval.encrypt_real(&z, 3), t.eval.encrypt_real(&z, 3))
        })
        .collect();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let svc = &svc;
        for chunk in inputs.chunks(total_ops.div_ceil(feeders)) {
            s.spawn(move || {
                for (tid, a, b) in chunk {
                    let out = svc
                        .eval(*tid, WireOp::Mul, 0, vec![a.clone(), b.clone()])
                        .expect("service eval");
                    std::hint::black_box(out);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let ops_per_s = if secs > 0.0 { total_ops as f64 / secs } else { 0.0 };
    let batches = svc
        .sched
        .metrics
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "service_batch_throughput: {total_ops} HMul ops from 2 tenants in {secs:.3}s \
         ({ops_per_s:.1} ops/s, {batches} batches)"
    );
    records.push(Record {
        // Aggregate-throughput record: median_ns holds the MEAN ns/op of
        // the whole concurrent run (not a per-op median) and the serial
        // baseline is not measured — same convention as the batched-CKKS
        // record above.
        name: format!(
            "service hmul 2 tenants x {feeders} feeders (max_batch=4, func_tiny; \
             median_ns = mean ns/op of run, no serial baseline)"
        ),
        threads: feeders,
        median_ns: secs * 1e9 / total_ops as f64,
        speedup_vs_serial: 0.0,
    });
    svc.shutdown();
    ops_per_s
}

fn write_json(
    path: &str,
    records: &[Record],
    bit_identical: bool,
    ntt_speedup: f64,
    fourstep_speedup: f64,
    tiled_hmul_speedup: f64,
    lazy_chain_speedup: f64,
    tiled_batch_hmul_speedup: f64,
    service_ops_per_s: f64,
    compiled_helr_speedup: f64,
    hoisted_ks_reduction: f64,
    bsgs_reduction_c2s: f64,
    bootstrap_cycles: f64,
) {
    let machine = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let results = Json::Array(
        records
            .iter()
            .map(|r| {
                Json::obj([
                    ("name", Json::Str(r.name.clone())),
                    ("threads", Json::Num(r.threads as u64)),
                    ("median_ns", Json::Float(r.median_ns)),
                    ("speedup_vs_serial", Json::Float(r.speedup_vs_serial)),
                ])
            })
            .collect(),
    );
    let doc = Json::obj([
        ("bench", Json::Str("hotpath".into())),
        ("machine_threads", Json::Num(machine as u64)),
        ("parallel_bit_identical_to_serial", Json::Bool(bit_identical)),
        (
            "ntt_precomputed_speedup_vs_naive_n8192",
            Json::Float(ntt_speedup),
        ),
        (
            "ntt_fourstep_speedup_vs_radix2_n32768",
            Json::Float(fourstep_speedup),
        ),
        (
            "tiled_hmul_speedup_vs_flat_n32768",
            Json::Float(tiled_hmul_speedup),
        ),
        ("lazy_chain_speedup_n32768", Json::Float(lazy_chain_speedup)),
        (
            "tiled_batch_hmul_speedup_vs_flat_batch_n32768",
            Json::Float(tiled_batch_hmul_speedup),
        ),
        (
            "service_batch_throughput_ops_per_s",
            Json::Float(service_ops_per_s),
        ),
        (
            "compiled_helr_speedup_vs_handwritten",
            Json::Float(compiled_helr_speedup),
        ),
        (
            "hoisted_keyswitch_reduction_helr",
            Json::Float(hoisted_ks_reduction),
        ),
        (
            "bsgs_keyswitch_reduction_c2s",
            Json::Float(bsgs_reduction_c2s),
        ),
        ("bootstrap_cycles_n32768", Json::Float(bootstrap_cycles)),
        ("results", results),
    ]);
    match std::fs::write(path, doc.write_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

fn main() {
    let args = Args::from_env();
    fhemem::parallel::configure_threads(args.threads());
    let mut records = Vec::new();

    // L3 substrate: single-row NTT at artifact and functional sizes.
    for logn in [11usize, 13] {
        let n = 1 << logn;
        let q = ntt_primes(40, n, 1)[0].q;
        let t = NttContext::get(q, n);
        let mut rng = SplitMix64::new(5);
        let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        let mut buf = data.clone();
        let s = bench_fn(&format!("ntt_forward n=2^{logn}"), || {
            buf.copy_from_slice(&data);
            t.forward(&mut buf);
            std::hint::black_box(&buf);
        });
        let butterflies = (n / 2 * logn) as f64;
        println!("    -> {:.1} M butterflies/s", butterflies / s.median.as_secs_f64() / 1e6);
    }

    // The NTT engine: precomputed Shoup/Harvey context vs the naive
    // (regenerate-roots, full-reduction) baseline. CI fails if ≤ 1x.
    let ntt_speedup = bench_ntt_engine_vs_naive(&mut records);

    // The four-step bank-tiled NTT vs the radix-2 kernel at N=2^15
    // (CI-gated > 1.0) and the tiled HMul hot path vs flat at logN=15.
    let fourstep_speedup = bench_fourstep_vs_radix2(&mut records);
    let tiled_hmul_speedup = bench_tiled_hmul_vs_flat(&mut records);

    // The lazy [0,2q) discipline across whole op chains (CI-gated > 1.0)
    // and the generic CtRepr batch fan-out, tiled vs flat.
    let lazy_chain_speedup = bench_lazy_chain(&mut records);
    let tiled_batch_hmul_speedup = bench_tiled_batch_hmul_vs_flat_batch(&mut records);

    // The bank-pool engine: batched limb-parallel NTT (acceptance: ≥2x
    // at N=8192 with ≥4 threads) + batched CKKS HMul.
    let bit_identical = bench_batched_ntt(&mut records);
    bench_batched_ckks(&mut records);

    // The serving layer: multi-tenant batched throughput through the
    // keystore + scheduler + mixed-batch coordinator path.
    let service_ops_per_s = bench_service_throughput(&mut records);

    // fhemem-compile: one HELR iteration as a compiled program vs the
    // hand-written evaluator path (CI gates the keyswitch reduction).
    let (compiled_helr_speedup, hoisted_ks_reduction) = bench_compiled_helr(&mut records);

    // Bootstrapping as a compiled program: BSGS keyswitch reduction on
    // the CoeffToSlot transform (CI-gated > 1.0) + the paper-scale
    // static cycle figure.
    let (bsgs_reduction_c2s, bootstrap_cycles) = bench_compiled_bootstrap(&mut records);

    // CKKS ops at func_default (logN=12, L=8, dnum=4).
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 1));
    let ev = Evaluator::new(ctx.clone(), chain, 2);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 97) as f64).collect();
    let a = ev.encrypt_real(&z, ctx.l());
    let b = ev.encrypt_real(&z, ctx.l());
    // warm the key cache so the bench measures the op, not keygen
    let _ = ev.mul(&a, &b);
    let _ = ev.rotate(&a, 1);
    bench_fn("ckks_hadd logN=12 L=8", || {
        std::hint::black_box(ev.add(&a, &b));
    });
    bench_fn("ckks_hmul(+KS+rescale) logN=12 L=8", || {
        std::hint::black_box(ev.mul(&a, &b));
    });
    bench_fn("ckks_rotate logN=12 L=8", || {
        std::hint::black_box(ev.rotate(&a, 1));
    });

    // Simulator engine throughput.
    bench_fn("sim_engine full resnet20 trace", || {
        std::hint::black_box(simulate(
            &ArchConfig::default(),
            &workloads::resnet20(),
            SimOptions::default(),
        ));
    });

    if let Some(path) = args.get("json") {
        write_json(
            path,
            &records,
            bit_identical,
            ntt_speedup,
            fourstep_speedup,
            tiled_hmul_speedup,
            lazy_chain_speedup,
            tiled_batch_hmul_speedup,
            service_ops_per_s,
            compiled_helr_speedup,
            hoisted_ks_reduction,
            bsgs_reduction_c2s,
            bootstrap_cycles,
        );
    }
}
