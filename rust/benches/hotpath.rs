//! Hot-path microbenches (§Perf): the Rust CKKS primitives and the
//! simulator engine itself. Used for the performance pass — before/after
//! numbers recorded in EXPERIMENTS.md §Perf.

use fhemem::ckks::{CkksContext, Evaluator, KeyChain};
use fhemem::math::ntt::NttTable;
use fhemem::math::primes::ntt_primes;
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::bench::bench_fn;
use fhemem::util::check::SplitMix64;
use std::sync::Arc;

fn main() {
    // L3 substrate: NTT at artifact and functional sizes.
    for logn in [11usize, 13] {
        let n = 1 << logn;
        let q = ntt_primes(40, n, 1)[0].q;
        let t = NttTable::new(q, n);
        let mut rng = SplitMix64::new(5);
        let data: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        let mut buf = data.clone();
        let s = bench_fn(&format!("ntt_forward n=2^{logn}"), || {
            buf.copy_from_slice(&data);
            t.forward(&mut buf);
            std::hint::black_box(&buf);
        });
        let butterflies = (n / 2 * logn) as f64;
        println!("    -> {:.1} M butterflies/s", butterflies / s.median.as_secs_f64() / 1e6);
    }

    // CKKS ops at func_default (logN=12, L=8, dnum=4).
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 1));
    let ev = Evaluator::new(ctx.clone(), chain, 2);
    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots).map(|i| 0.001 * (i % 97) as f64).collect();
    let a = ev.encrypt_real(&z, ctx.l());
    let b = ev.encrypt_real(&z, ctx.l());
    // warm the key cache so the bench measures the op, not keygen
    let _ = ev.mul(&a, &b);
    let _ = ev.rotate(&a, 1);
    bench_fn("ckks_hadd logN=12 L=8", || {
        std::hint::black_box(ev.add(&a, &b));
    });
    bench_fn("ckks_hmul(+KS+rescale) logN=12 L=8", || {
        std::hint::black_box(ev.mul(&a, &b));
    });
    bench_fn("ckks_rotate logN=12 L=8", || {
        std::hint::black_box(ev.rotate(&a, 1));
    });

    // Simulator engine throughput.
    bench_fn("sim_engine full resnet20 trace", || {
        std::hint::black_box(simulate(
            &ArchConfig::default(),
            &workloads::resnet20(),
            SimOptions::default(),
        ));
    });
}
