//! Regenerates every table and figure of the paper's evaluation
//! (Fig. 1, Fig. 3, Fig. 12, Fig. 13, Fig. 14, Fig. 15, Table III),
//! printing paper-reported vs measured values side by side, plus wall
//! time for each regeneration (this is the `cargo bench` entry point).

use fhemem::baselines::{asic, bandwidth, pim};
use fhemem::report;
use fhemem::sim::{area, simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::bench::bench_fn;

fn fig1() {
    println!("\n===== Fig 1: working sets + bandwidth requirements =====");
    for log_n in [15usize, 16, 17] {
        let p = bandwidth::Fig1Params::paper(log_n);
        println!(
            "logN={log_n}: HMul working set {:.0} MB (paper: 98–390 MB across logN 15–17)",
            p.hmul_working_set_bytes() / 1e6
        );
    }
    let p = bandwidth::Fig1Params::paper(17);
    println!("{}", report::compare_row(
        "2k NTTUs, evk-only load (TB/s)",
        1.5,
        p.required_bandwidth(2048, 1.0, bandwidth::Scenario::EvkOnly) / 1e12,
    ));
    println!("{}", report::compare_row(
        "2k NTTUs, evk+2 operands (TB/s)",
        3.0,
        p.required_bandwidth(2048, 1.0, bandwidth::Scenario::EvkPlusTwoOperands) / 1e12,
    ));
    println!("{}", report::compare_row(
        "64k NTTUs, evk+2 operands (TB/s)",
        100.0,
        p.required_bandwidth(65536, 1.0, bandwidth::Scenario::EvkPlusTwoOperands) / 1e12,
    ));
}

fn fig3() {
    println!("\n===== Fig 3: 32-bit multiply throughput/energy across PIM =====");
    let cfg = ArchConfig::new(8, 8192);
    let s = pim::simdram(&cfg, 32);
    let f = pim::fimdram(&cfg);
    let d = pim::drisa_logic(&cfg);
    println!("{}", report::compare_row("FIMDRAM throughput (TB/s)", 6.8, f.mult_tbps));
    println!("{}", report::compare_row("FIMDRAM energy (pJ/op)", 49.8, f.energy_per_op_pj));
    println!("{}", report::compare_row("SIMDRAM throughput (TB/s)", 180.6, s.mult_tbps));
    println!("{}", report::compare_row("SIMDRAM energy (pJ/op)", 342.9, s.energy_per_op_pj));
    println!("{}", report::compare_row("DRISA throughput (PB/s)", 3.0, d.mult_tbps / 1000.0));
    println!("{}", report::compare_row("DRISA energy (pJ/op)", 6.32, d.energy_per_op_pj));
}

fn fig12() {
    println!("\n===== Fig 12: FHEmem configs vs SHARP / CraterLake =====");
    println!("{}", report::sim_header());
    let mut rows = Vec::new();
    for cfg in [ArchConfig::new(2, 2048), ArchConfig::new(4, 4096), ArchConfig::new(8, 8192)] {
        for t in workloads::all() {
            let r = simulate(&cfg, &t, SimOptions::default());
            println!("{}", report::sim_row(&r));
            rows.push(r);
        }
    }
    println!("--- ASIC baselines (analytic, published hardware) ---");
    let mut speedups = Vec::new();
    for t in workloads::all() {
        let sharp = asic::run(&asic::sharp(), &t);
        let clake = asic::run(&asic::craterlake(), &t);
        println!(
            "{:<14} SHARP {:>10.3} ms   CraterLake {:>10.3} ms",
            t.name,
            sharp.latency_s * 1e3,
            clake.latency_s * 1e3
        );
        if let Some(r) = rows.iter().find(|r| r.workload == t.name && r.config.ar == 8) {
            speedups.push((t.name, sharp.latency_s / r.latency_s, clake.latency_s / r.latency_s));
        }
    }
    println!("--- ARx8-8k speedups (paper: 4.4x/2.2x/5.4x vs SHARP on boot/HELR/ResNet) ---");
    for (name, s_sharp, s_clake) in speedups {
        println!("{name:<14} vs SHARP {s_sharp:>6.2}x   vs CraterLake {s_clake:>6.2}x");
    }
}

fn fig13() {
    println!("\n===== Fig 13: latency & energy breakdown =====");
    for cfg in [ArchConfig::new(1, 1024), ArchConfig::new(4, 4096), ArchConfig::new(8, 8192)] {
        for t in [workloads::bootstrapping(), workloads::resnet20()] {
            let r = simulate(&cfg, &t, SimOptions::default());
            let b = &r.breakdown;
            let tot = b.total().cycles.max(1.0);
            println!(
                "{:<9} {:<14} comp {:>4.1}% perm {:>4.1}% rw {:>4.1}% interbank {:>4.1}% chan {:>4.1}% stack {:>4.1}%",
                cfg.name(), t.name,
                100.0 * b.computation.cycles / tot,
                100.0 * b.permutation.cycles / tot,
                100.0 * b.read_write.cycles / tot,
                100.0 * b.interbank.cycles / tot,
                100.0 * b.channel.cycles / tot,
                100.0 * b.stack.cycles / tot,
            );
        }
    }
}

fn fig14() {
    println!("\n===== Fig 14: FHEmem vs PIM technologies (end-to-end) =====");
    let cfg = ArchConfig::new(4, 4096);
    let t = workloads::bootstrapping();
    let fhe = simulate(&cfg, &t, SimOptions::default());
    for p in [pim::simdram(&cfg, 64), pim::drisa_logic(&cfg), pim::drisa_add(&cfg)] {
        let latency = fhe.latency_s * p.e2e_slowdown_vs_fhemem;
        println!(
            "{:<14} {:>10.3} ms  ({}x vs FHEmem; paper: SIMDRAM 183-255x, DRISA-logic 2.8-6.8x, DRISA-add 0.85x)",
            p.name,
            latency * 1e3,
            p.e2e_slowdown_vs_fhemem
        );
    }
}

fn fig15() {
    println!("\n===== Fig 15: optimization ablations =====");
    for (ar, w) in [(2u32, 2048u32), (4, 4096), (8, 8192)] {
        let cfg = ArchConfig::new(ar, w);
        for t in [workloads::helr(), workloads::resnet20()] {
            let full = simulate(&cfg, &t, SimOptions::default());
            let base0 = simulate(&cfg, &t, SimOptions { montgomery: false, ..Default::default() });
            let base1 = simulate(&cfg, &t, SimOptions { interbank_chain: false, ..Default::default() });
            let base2 = simulate(&cfg, &t, SimOptions { load_save: false, ..Default::default() });
            println!(
                "{:<9} {:<10} montgomery {:>5.2}x  interbank {:>5.2}x  load-save {:>5.2}x",
                cfg.name(), t.name,
                base0.latency_s / full.latency_s,
                base1.latency_s / full.latency_s,
                base2.latency_s / full.latency_s,
            );
        }
    }
    println!("(paper: montgomery 1.06-1.68x, interbank 1.31-2.12x, load-save 1.15-3.59x)");
}

fn table3() {
    println!("\n===== Table III: area/power of FHEmem (16GB stack, ARx4/4k) =====");
    let cfg = ArchConfig::new(4, 4096);
    let a = area::stack_area(&cfg);
    println!("{}", report::compare_row("DRAM total (mm2)", 148.33, a.dram_total()));
    println!("{}", report::compare_row("Horizontal DLs (mm2)", 14.13, a.hdl));
    println!("{}", report::compare_row("Adders & latches (mm2)", 30.43, a.adders_latches));
    println!("{}", report::compare_row("Bank chain & buf (mm2)", 0.065, a.chain));
    println!("{}", report::compare_row("Control logic (mm2)", 0.56, a.control));
    println!("{}", report::compare_row("ARx1-1k total area (mm2)", 223.81, area::total_area_mm2(&ArchConfig::new(1, 1024))));
    println!("{}", report::compare_row("ARx8-8k total area (mm2)", 642.32, area::total_area_mm2(&ArchConfig::new(8, 8192))));
}

fn main() {
    bench_fn("fig1_bandwidth_model", || {
        let p = bandwidth::Fig1Params::paper(17);
        std::hint::black_box(p.required_bandwidth(2048, 1.0, bandwidth::Scenario::EvkOnly));
    });
    bench_fn("fig12_full_design_point (sim helr)", || {
        std::hint::black_box(simulate(
            &ArchConfig::default(),
            &workloads::helr(),
            SimOptions::default(),
        ));
    });
    bench_fn("fig12_bootstrapping_sim", || {
        std::hint::black_box(simulate(
            &ArchConfig::new(8, 8192),
            &workloads::bootstrapping(),
            SimOptions::default(),
        ));
    });
    fig1();
    fig3();
    fig12();
    fig13();
    fig14();
    fig15();
    table3();
    println!("\nall figures regenerated OK");
}
