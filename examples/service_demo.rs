//! Serving-layer demo: two tenants encrypt locally, evaluate remotely
//! through the batching TCP front-end, and decrypt their own results.
//!
//! Standalone (spawns an in-process server on an ephemeral port):
//!
//! ```sh
//! cargo run --release --example service_demo
//! ```
//!
//! Against an already-running `fhemem serve` (the CI smoke job's mode):
//!
//! ```sh
//! cargo run --release -- serve --port 7171 &
//! cargo run --release --example service_demo -- --port 7171
//! ```

use fhemem::params::CkksParams;
use fhemem::service::{server, FheService, SchedulerConfig, ServiceClient};
use fhemem::sim::ArchConfig;
use fhemem::util::cli::Args;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    fhemem::parallel::configure_threads(args.threads());

    // Either connect to an external server or bring one up in-process.
    let (addr, local) = match args.get("port") {
        Some(_) => {
            let port = args.get_port("port", 7070);
            (format!("127.0.0.1:{port}"), None)
        }
        None => {
            let svc = FheService::new(
                ArchConfig::default(),
                SchedulerConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(50),
                    max_queue: 64,
                    max_tenant_inflight: 0,
                },
            );
            let handle = server::spawn("127.0.0.1:0", svc.clone()).expect("bind ephemeral port");
            println!("in-process server on {}", handle.addr);
            (handle.addr.to_string(), Some((svc, handle)))
        }
    };

    // Two tenants with independent key material.
    let mut alice =
        ServiceClient::connect(&addr, 1, CkksParams::func_tiny(), 0xA11CE).expect("register alice");
    let mut bob =
        ServiceClient::connect(&addr, 2, CkksParams::func_tiny(), 0xB0B).expect("register bob");

    let slots = alice.ctx.encoder.slots();
    let xs: Vec<f64> = (0..slots).map(|i| 0.1 * ((i % 7) as f64 - 3.0)).collect();
    let ys: Vec<f64> = (0..slots).map(|i| 0.05 * ((i % 5) as f64)).collect();

    // Fresh ciphertexts go out seed-compressed (~half the bytes).
    let ax = alice.encrypt(&xs, 3);
    let ay = alice.encrypt(&ys, 3);
    let bx = bob.encrypt(&xs, 3);

    // Concurrent requests from both tenants share batching windows.
    let (prod, rot) = std::thread::scope(|s| {
        let h = s.spawn(|| {
            let prod = alice.mul(&ax, &ay).expect("alice hmul");
            let rot = alice.rotate(&ax, 2).expect("alice hrot");
            (prod, rot)
        });
        let bsum = bob.add(&bx, &bx).expect("bob hadd");
        let dec = bob.decrypt(&bsum);
        let worst = (0..slots)
            .map(|i| (dec[i] - 2.0 * xs[i]).abs())
            .fold(0.0f64, f64::max);
        println!("bob   : hadd worst slot error {worst:.2e}");
        assert!(worst < 1e-2, "bob's homomorphic sum diverged");
        h.join().expect("alice thread")
    });

    let d_prod = alice.decrypt(&prod);
    let d_rot = alice.decrypt(&rot);
    let mut worst = 0.0f64;
    for i in 0..slots {
        worst = worst.max((d_prod[i] - xs[i] * ys[i]).abs());
        worst = worst.max((d_rot[i] - xs[(i + 2) % slots]).abs());
    }
    println!("alice : hmul+hrot worst slot error {worst:.2e}");
    assert!(worst < 1e-2, "alice's homomorphic results diverged");

    let metrics = alice.metrics().expect("metrics");
    println!("scheduler metrics:\n{metrics}");

    if let Some((svc, handle)) = local {
        handle.stop();
        svc.shutdown();
    }
    println!("service_demo OK");
}
