//! Design-space exploration (paper Fig. 12 / §VI-B): sweep AR × adder
//! width across all six workloads, report latency/EDP/EDAP, and identify
//! the lowest-EDP and lowest-EDAP configurations (paper: ARx8-8k and
//! ARx4-4k respectively).
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use fhemem::report;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;

fn main() {
    println!("{}", report::sim_header());
    let mut best_edp: Option<(f64, String)> = None;
    let mut best_edap: Option<(f64, String)> = None;
    for cfg in ArchConfig::design_space() {
        let mut edp_sum = 0.0;
        let mut edap_sum = 0.0;
        for t in workloads::deep() {
            let r = simulate(&cfg, &t, SimOptions::default());
            println!("{}", report::sim_row(&r));
            edp_sum += r.edp().log10();
            edap_sum += r.edap().log10();
        }
        // geometric-mean EDP/EDAP over deep workloads
        if best_edp.as_ref().map(|(v, _)| edp_sum < *v).unwrap_or(true) {
            best_edp = Some((edp_sum, cfg.name()));
        }
        if best_edap.as_ref().map(|(v, _)| edap_sum < *v).unwrap_or(true) {
            best_edap = Some((edap_sum, cfg.name()));
        }
    }
    let (_, edp_name) = best_edp.unwrap();
    let (_, edap_name) = best_edap.unwrap();
    println!("\nlowest-EDP config:  {edp_name}   (paper: ARx8-8k)");
    println!("lowest-EDAP config: {edap_name}   (paper: ARx4-4k)");
    println!("design_space OK");
}
