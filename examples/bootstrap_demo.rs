//! Bootstrapping demo: refresh an exhausted ciphertext with the full
//! ModRaise → CoeffToSlot → EvalMod → SlotToCoeff pipeline — once flat,
//! once as a compiled program on the tiled hot path (bit-identical) —
//! verify the message survives, and print the per-stage simulated cost
//! of the paper-scale bootstrapping workload on FHEmem.
//!
//! ```sh
//! cargo run --release --example bootstrap_demo
//! ```

use fhemem::ckks::bootstrap::BootstrapConfig;
use fhemem::ckks::{CkksContext, Evaluator, KeyChain};
use fhemem::coordinator::Coordinator;
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let ctx = CkksContext::new(CkksParams::func_boot());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 42));
    let ev = Arc::new(Evaluator::new(ctx.clone(), chain, 43));
    let bs = BootstrapConfig::default().build(&ev);
    println!(
        "bootstrapper: K={}, r={}, depth={} levels (of L={})",
        bs.k_bound,
        bs.r_doubles,
        bs.depth,
        ctx.l()
    );

    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots)
        .map(|i| 0.4 * (2.0 * std::f64::consts::PI * i as f64 / slots as f64).sin())
        .collect();
    let ct = ev.encrypt_real(&z, ctx.l());
    let exhausted = ev.level_down(&ct, 1);
    println!("input at level 1 (multiplicatively exhausted)");

    let t0 = Instant::now();
    let refreshed = bs.bootstrap(&ev, &exhausted);
    let wall = t0.elapsed();
    let dec = ev.decrypt_real(&refreshed);
    let worst = z
        .iter()
        .zip(&dec)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "refreshed to level {} in {wall:?}; worst slot error {worst:.3e}",
        refreshed.level
    );
    assert!(worst < 5e-2, "bootstrap numerics diverged");

    // A refreshed ciphertext supports further multiplication when the
    // parameter set leaves headroom above the bootstrap depth.
    if refreshed.level >= 2 {
        let sq = ev.square(&refreshed);
        let dsq = ev.decrypt_real(&sq);
        let e2 = z
            .iter()
            .zip(&dsq)
            .map(|(a, b)| (a * a - b).abs())
            .fold(0.0f64, f64::max);
        println!("post-bootstrap square error {e2:.3e}");
    } else {
        println!("refreshed at level {} — add q-limbs for post-boot multiplies", refreshed.level);
    }

    // The same pipeline compiled to a program graph and executed tiled
    // through the coordinator, with BSGS sibling-rotation hoisting.
    let coord = Coordinator::new(CkksParams::func_boot(), ArchConfig::default(), None);
    let t1 = Instant::now();
    let (compiled, report) = bs
        .bootstrap_compiled(&coord, &ev, &exhausted)
        .expect("compiled bootstrap executes");
    let wall_c = t1.elapsed();
    assert_eq!(compiled.c0.data, refreshed.c0.data, "compiled != flat (c0)");
    assert_eq!(compiled.c1.data, refreshed.c1.data, "compiled != flat (c1)");
    println!(
        "compiled+tiled bootstrap bit-identical in {wall_c:?}; {} nodes, {} waves, {} keyswitch pipelines, {} sim cycles",
        report.nodes_executed, report.waves, report.keyswitch_invocations, report.sim_cycles
    );

    println!("\n== paper-scale bootstrapping on simulated FHEmem ==");
    let t = workloads::bootstrapping();
    for cfg in [ArchConfig::new(2, 2048), ArchConfig::new(4, 4096), ArchConfig::new(8, 8192)] {
        let r = simulate(&cfg, &t, SimOptions::default());
        println!(
            "{:<9} {:>10.3} ms/input  {:>9.3e} J  breakdown: comp {:.0}% perm {:.0}% interbank {:.0}%",
            cfg.name(),
            r.latency_s * 1e3,
            r.energy_j,
            100.0 * r.breakdown.computation.cycles / r.breakdown.total().cycles,
            100.0 * r.breakdown.permutation.cycles / r.breakdown.total().cycles,
            100.0 * r.breakdown.interbank.cycles / r.breakdown.total().cycles,
        );
    }
    println!("bootstrap_demo OK");
}
