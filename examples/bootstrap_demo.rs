//! Bootstrapping demo: refresh an exhausted ciphertext with the full
//! ModRaise → CoeffToSlot → EvalMod → SlotToCoeff pipeline, verify the
//! message survives, and print the per-stage simulated cost of the
//! paper-scale bootstrapping workload on FHEmem.
//!
//! ```sh
//! cargo run --release --example bootstrap_demo
//! ```

use fhemem::ckks::bootstrap::Bootstrapper;
use fhemem::ckks::{CkksContext, Evaluator, KeyChain};
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let ctx = CkksContext::new(CkksParams::func_boot());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 42));
    let ev = Evaluator::new(ctx.clone(), chain, 43);
    let bs = Bootstrapper::new(&ev, 16.0, 3, 30);
    println!(
        "bootstrapper: K={}, r={}, depth={} levels (of L={})",
        bs.k_bound,
        bs.r_doubles,
        bs.depth,
        ctx.l()
    );

    let slots = ctx.encoder.slots();
    let z: Vec<f64> = (0..slots)
        .map(|i| 0.4 * (2.0 * std::f64::consts::PI * i as f64 / slots as f64).sin())
        .collect();
    let ct = ev.encrypt_real(&z, ctx.l());
    let exhausted = ev.level_down(&ct, 1);
    println!("input at level 1 (multiplicatively exhausted)");

    let t0 = Instant::now();
    let refreshed = bs.bootstrap(&ev, &exhausted);
    let wall = t0.elapsed();
    let dec = ev.decrypt_real(&refreshed);
    let worst = z
        .iter()
        .zip(&dec)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "refreshed to level {} in {wall:?}; worst slot error {worst:.3e}",
        refreshed.level
    );
    assert!(worst < 5e-2, "bootstrap numerics diverged");

    // A refreshed ciphertext supports further multiplication when the
    // parameter set leaves headroom above the bootstrap depth.
    if refreshed.level >= 2 {
        let sq = ev.square(&refreshed);
        let dsq = ev.decrypt_real(&sq);
        let e2 = z
            .iter()
            .zip(&dsq)
            .map(|(a, b)| (a * a - b).abs())
            .fold(0.0f64, f64::max);
        println!("post-bootstrap square error {e2:.3e}");
    } else {
        println!("refreshed at level {} — add q-limbs for post-boot multiplies", refreshed.level);
    }

    println!("\n== paper-scale bootstrapping on simulated FHEmem ==");
    let t = workloads::bootstrapping();
    for cfg in [ArchConfig::new(2, 2048), ArchConfig::new(4, 4096), ArchConfig::new(8, 8192)] {
        let r = simulate(&cfg, &t, SimOptions::default());
        println!(
            "{:<9} {:>10.3} ms/input  {:>9.3e} J  breakdown: comp {:.0}% perm {:.0}% interbank {:.0}%",
            cfg.name(),
            r.latency_s * 1e3,
            r.energy_j,
            100.0 * r.breakdown.computation.cycles / r.breakdown.total().cycles,
            100.0 * r.breakdown.permutation.cycles / r.breakdown.total().cycles,
            100.0 * r.breakdown.interbank.cycles / r.breakdown.total().cycles,
        );
    }
    println!("bootstrap_demo OK");
}
