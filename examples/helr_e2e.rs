//! End-to-end driver (DESIGN.md "End-to-end validation"): homomorphic
//! logistic-regression training in the HELR shape — encrypted weights ×
//! plaintext features, a hoisted rotation-sum dot product, polynomial
//! sigmoid, encrypted gradient — on synthetic data, with the decrypted
//! loss logged per iteration.
//!
//! This is also the flagship consumer of `fhemem-compile`: every
//! iteration is built twice — once hand-written against the evaluator,
//! once as a `program::Builder` graph compiled through CSE + rotation
//! hoisting + auto-rescale and executed tiled through the coordinator —
//! and the two gradients must agree **bit for bit**. The coordinator
//! simultaneously costs the compiled run on FHEmem ARx4-4k, reported
//! against the SHARP / CraterLake analytic baselines.
//!
//! ```sh
//! cargo run --release --example helr_e2e
//! ```

use fhemem::baselines::asic;
use fhemem::ckks::linear::{chebyshev_fit, eval_chebyshev};
use fhemem::ckks::{CkksContext, Evaluator, KeyChain};
use fhemem::coordinator::Coordinator;
use fhemem::params::CkksParams;
use fhemem::program::{compile, Builder, PassOptions};
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::check::SplitMix64;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    let coord = Coordinator::new(CkksParams::func_default(), ArchConfig::default(), None);
    println!("backend: {}", coord.backend_name());
    // The workload's own key material (shared by the hand-written path
    // and the compiled program, so outputs are comparable bit-for-bit).
    let ctx = CkksContext::new(CkksParams::func_default());
    let chain = Arc::new(KeyChain::new(ctx.clone(), 0x4E15));
    let ev = Arc::new(Evaluator::new(ctx.clone(), chain, 0x4E16));
    let slots = ev.ctx.encoder.slots();

    // ---- synthetic binary-classification data, packed across slots ----
    let features = 16usize;
    let samples = slots / features;
    let mut rng = SplitMix64::new(7);
    let true_w: Vec<f64> = (0..features).map(|_| rng.f64() - 0.5).collect();
    // x packed sample-major: slot s*features + f = feature f of sample s
    let mut x = vec![0.0f64; slots];
    let mut y = vec![0.0f64; slots];
    for s in 0..samples {
        let mut dot = 0.0;
        for f in 0..features {
            let v = rng.f64() * 2.0 - 1.0;
            x[s * features + f] = v;
            dot += v * true_w[f];
        }
        let label = if dot > 0.0 { 1.0 } else { 0.0 };
        for f in 0..features {
            y[s * features + f] = label;
        }
    }

    let mut w_plain = vec![0.0f64; features];
    let sigmoid_coeffs = chebyshev_fit(|t| 1.0 / (1.0 + (-2.0 * t).exp()), 4);
    let lr = 0.5;
    let iters = 4; // level budget: each iteration costs ~4 levels

    // ---- one HELR iteration as a compiled program ----
    let program = {
        let mut b = Builder::new();
        let w = b.input("w");
        let xw = b.mul_plain(w, x.clone());
        let dot = b.rotate_sum(xw, features); // log-tree; hoisted by the planner
        let pred = b.chebyshev(dot, sigmoid_coeffs.clone());
        let err = b.sub_plain_vec(pred, y.clone());
        let grad = b.mul_plain(err, x.clone());
        b.output("grad", grad);
        b.output("pred", pred);
        b.build().expect("HELR graph builds")
    };
    let level = ev.ctx.l();
    let inputs_meta: HashMap<String, (usize, f64)> =
        HashMap::from([("w".to_string(), (level, ev.ctx.scale()))]);
    let compiled = compile(&program, &ev.ctx, &inputs_meta, &PassOptions::default())
        .expect("HELR program compiles");
    let unhoisted = compile(
        &program,
        &ev.ctx,
        &inputs_meta,
        &PassOptions {
            hoist_rotations: false,
            ..PassOptions::default()
        },
    )
    .expect("unhoisted compile");
    println!(
        "program: {} nodes in {} waves; keyswitch pipelines {} hoisted vs {} unhoisted \
         ({:.1}x fewer)",
        compiled.program.nodes.len(),
        compiled.waves.len(),
        compiled.counts.keyswitch_invocations,
        unhoisted.counts.keyswitch_invocations,
        unhoisted.counts.keyswitch_invocations as f64
            / compiled.counts.keyswitch_invocations as f64,
    );

    println!("iter   loss(enc)   loss(plain)  sim-us");
    for it in 0..iters {
        // fresh encryption of current weights each iteration (HELR
        // re-encrypts between bootstrap sections; our depth budget maps
        // one iteration per refresh)
        let w_packed: Vec<f64> = (0..slots).map(|i| w_plain[i % features]).collect();
        let cw = ev.encrypt_real(&w_packed, level);

        // ---- hand-written path (the conformance baseline) ----
        let xw = ev.mul_plain(&cw, &x);
        let dot = ev.rotate_sum_hoisted(&xw, features);
        let pred_hand = eval_chebyshev(&ev, &dot, &sigmoid_coeffs);
        let err = ev.sub_plain(&pred_hand, &y);
        let grad_hand = ev.mul_plain(&err, &x);

        // ---- compiled path: same ciphertext through the planner +
        // tiled mixed-batch executor ----
        let run = compiled
            .execute(&coord, &ev, &HashMap::from([("w".to_string(), cw)]))
            .expect("compiled HELR executes");
        let mut grad = None;
        let mut pred = None;
        for (name, ct) in &run.outputs {
            match name.as_str() {
                "grad" => grad = Some(ct.clone()),
                "pred" => pred = Some(ct.clone()),
                _ => {}
            }
        }
        let (grad, pred) = (grad.expect("grad output"), pred.expect("pred output"));
        assert_eq!(
            grad.c0.data, grad_hand.c0.data,
            "compiled gradient diverged from hand-written (c0)"
        );
        assert_eq!(
            grad.c1.data, grad_hand.c1.data,
            "compiled gradient diverged from hand-written (c1)"
        );

        // decrypt to update weights (client-side step, as in HELR's
        // per-refresh protocol) and log the loss
        let g = ev.decrypt_real(&grad);
        let p = ev.decrypt_real(&pred);
        let mut loss = 0.0;
        for s in 0..samples {
            let label = y[s * features];
            let pr = p[s * features].clamp(1e-6, 1.0 - 1e-6);
            loss -= label * pr.ln() + (1.0 - label) * (1.0 - pr).ln();
        }
        loss /= samples as f64;
        // plaintext reference loss with the same weights
        let mut loss_ref = 0.0;
        for s in 0..samples {
            let mut d = 0.0;
            for f in 0..features {
                d += x[s * features + f] * w_plain[f];
            }
            let pr = (1.0 / (1.0 + (-2.0 * d).exp())).clamp(1e-6, 1.0 - 1e-6);
            let label = y[s * features];
            loss_ref -= label * pr.ln() + (1.0 - label) * (1.0 - pr).ln();
        }
        loss_ref /= samples as f64;

        for f in 0..features {
            let mut gf = 0.0;
            for s in 0..samples {
                gf += g[s * features + f];
            }
            w_plain[f] -= lr * gf / samples as f64;
        }
        println!(
            "{it:>4}   {loss:>9.4}   {loss_ref:>10.4}  {:>7.1}",
            coord.simulated_seconds() * 1e6
        );
        assert!(
            (loss - loss_ref).abs() < 0.15,
            "encrypted loss diverged from plaintext reference"
        );
    }

    // ---- accelerator-level report: paper workload trace on FHEmem ----
    println!("\n== paper-scale HELR on simulated FHEmem vs ASIC baselines ==");
    let t = workloads::helr();
    let fhe = simulate(&coord.arch, &t, SimOptions::default());
    let sharp = asic::run(&asic::sharp(), &t);
    let clake = asic::run(&asic::craterlake(), &t);
    println!(
        "FHEmem {}: {:.3} ms/input   SHARP: {:.3} ms ({:.2}x)   CraterLake: {:.3} ms ({:.2}x)",
        coord.arch.name(),
        fhe.latency_s * 1e3,
        sharp.latency_s * 1e3,
        sharp.latency_s / fhe.latency_s,
        clake.latency_s * 1e3,
        clake.latency_s / fhe.latency_s,
    );
    println!("helr_e2e OK");
}
