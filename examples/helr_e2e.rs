//! End-to-end driver (DESIGN.md "End-to-end validation"): homomorphic
//! logistic-regression training in the HELR shape — encrypted features ×
//! encrypted weights, rotation-sum dot products, polynomial sigmoid,
//! encrypted gradient update — on synthetic data, with the decrypted loss
//! logged per iteration, while the coordinator simultaneously costs the
//! same trace on FHEmem ARx4-4k and reports it against the SHARP /
//! CraterLake analytic baselines.
//!
//! ```sh
//! cargo run --release --example helr_e2e
//! ```

use fhemem::baselines::asic;
use fhemem::ckks::linear::{chebyshev_fit, eval_chebyshev};
use fhemem::coordinator::Coordinator;
use fhemem::params::CkksParams;
use fhemem::sim::{simulate, ArchConfig, SimOptions};
use fhemem::trace::workloads;
use fhemem::util::check::SplitMix64;
use std::path::Path;

fn main() {
    let coord = Coordinator::new(
        CkksParams::func_default(),
        ArchConfig::default(),
        Some(Path::new("artifacts")),
    );
    println!("backend: {}", coord.backend_name());
    let ev = &coord.eval;
    let slots = coord.ctx.encoder.slots();

    // ---- synthetic binary-classification data, packed across slots ----
    let features = 16usize;
    let samples = slots / features;
    let mut rng = SplitMix64::new(7);
    let true_w: Vec<f64> = (0..features).map(|_| rng.f64() - 0.5).collect();
    // x packed sample-major: slot s*features + f = feature f of sample s
    let mut x = vec![0.0f64; slots];
    let mut y = vec![0.0f64; slots];
    for s in 0..samples {
        let mut dot = 0.0;
        for f in 0..features {
            let v = rng.f64() * 2.0 - 1.0;
            x[s * features + f] = v;
            dot += v * true_w[f];
        }
        let label = if dot > 0.0 { 1.0 } else { 0.0 };
        for f in 0..features {
            y[s * features + f] = label;
        }
    }

    // encrypted weights (replicated per sample block), plaintext features
    let mut w_plain = vec![0.0f64; features];
    let sigmoid_coeffs = chebyshev_fit(|t| 1.0 / (1.0 + (-2.0 * t).exp()), 4);
    let lr = 0.5;
    let iters = 4; // level budget: each iteration costs ~4 levels

    println!("iter   loss(enc)   loss(plain)  sim-us");
    for it in 0..iters {
        // fresh encryption of current weights each iteration (HELR
        // re-encrypts between bootstrap sections; our depth budget maps
        // one iteration per refresh)
        let w_packed: Vec<f64> = (0..slots).map(|i| w_plain[i % features]).collect();
        let cw = ev.encrypt_real(&w_packed, coord.ctx.l());

        // dot = rotate-sum(x ⊙ w) within each feature block
        let xw = {
            let t = ev.mul_plain(&cw, &x);
            coord.metrics.ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            t
        };
        let mut dot = xw.clone();
        let mut step = 1usize;
        while step < features {
            let r = coord.rotate(&dot, step as i64);
            dot = ev.add(&dot, &r);
            step <<= 1;
        }
        // sigmoid(dot) via homomorphic Chebyshev
        let pred = eval_chebyshev(ev, &dot, &sigmoid_coeffs);
        // error = pred - y ; gradient slot f = err ⊙ x (reduced later)
        let y_enc = ev.encode_plain(&y, pred.level, pred.scale);
        let mut err = pred.clone();
        err.c0.sub_assign(&{
            let mut p = y_enc.clone();
            p.to_ntt();
            p
        });
        let grad = ev.mul_plain(&err, &x);

        // decrypt to update weights (client-side step, as in HELR's
        // per-refresh protocol) and log the loss
        let g = ev.decrypt_real(&grad);
        let p = ev.decrypt_real(&pred);
        let mut loss = 0.0;
        for s in 0..samples {
            let label = y[s * features];
            let pr = p[s * features].clamp(1e-6, 1.0 - 1e-6);
            loss -= label * pr.ln() + (1.0 - label) * (1.0 - pr).ln();
        }
        loss /= samples as f64;
        // plaintext reference loss with the same weights
        let mut loss_ref = 0.0;
        for s in 0..samples {
            let mut d = 0.0;
            for f in 0..features {
                d += x[s * features + f] * w_plain[f];
            }
            let pr = (1.0 / (1.0 + (-2.0 * d).exp())).clamp(1e-6, 1.0 - 1e-6);
            let label = y[s * features];
            loss_ref -= label * pr.ln() + (1.0 - label) * (1.0 - pr).ln();
        }
        loss_ref /= samples as f64;

        for f in 0..features {
            let mut gf = 0.0;
            for s in 0..samples {
                gf += g[s * features + f];
            }
            w_plain[f] -= lr * gf / samples as f64;
        }
        println!(
            "{it:>4}   {loss:>9.4}   {loss_ref:>10.4}  {:>7.1}",
            coord.simulated_seconds() * 1e6
        );
        assert!(
            (loss - loss_ref).abs() < 0.15,
            "encrypted loss diverged from plaintext reference"
        );
    }

    // ---- accelerator-level report: paper workload trace on FHEmem ----
    println!("\n== paper-scale HELR on simulated FHEmem vs ASIC baselines ==");
    let t = workloads::helr();
    let fhe = simulate(&coord.arch, &t, SimOptions::default());
    let sharp = asic::run(&asic::sharp(), &t);
    let clake = asic::run(&asic::craterlake(), &t);
    println!(
        "FHEmem {}: {:.3} ms/input   SHARP: {:.3} ms ({:.2}x)   CraterLake: {:.3} ms ({:.2}x)",
        coord.arch.name(),
        fhe.latency_s * 1e3,
        sharp.latency_s * 1e3,
        sharp.latency_s / fhe.latency_s,
        clake.latency_s * 1e3,
        clake.latency_s / fhe.latency_s,
    );
    println!("helr_e2e OK");
}
