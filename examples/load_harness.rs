//! Synthetic fleet driver for the serving layer: open-loop arrivals
//! from a fleet of concurrent tenants, plus a block of fully idle
//! connections, against the readiness-loop front-end. Records p50/p99
//! latency and sustained ops/s into `BENCH_hotpath.json` (merged into
//! the existing document — the other bench figures are preserved).
//!
//! Standalone (spawns an in-process server on ephemeral ports):
//!
//! ```sh
//! cargo run --release --example load_harness -- --tenants 128 --ops 5
//! ```
//!
//! Against an already-running `fhemem serve` (the CI load-smoke job's
//! mode drives a loopback server):
//!
//! ```sh
//! cargo run --release --example load_harness -- --port 7171 --json BENCH_hotpath.json
//! ```
//!
//! **Open loop**: every op has a scheduled arrival time fixed up front
//! (fleet-wide Poisson-ish spread: tenant phases stagger uniformly);
//! latency is measured from the *scheduled* arrival, not the send, so
//! a server that falls behind shows the queueing delay in its tail —
//! the metric an SLO actually cares about.

use fhemem::params::CkksParams;
use fhemem::service::{server, FheService, SchedulerConfig, ServiceClient};
use fhemem::sim::ArchConfig;
use fhemem::util::cli::Args;
use fhemem::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    fhemem::parallel::configure_threads(args.threads());

    let tenants = args.get_usize("tenants", 128);
    let ops_per_tenant = args.get_usize("ops", 5);
    let rate = args.get_usize("rate", 100).max(1); // fleet-wide ops/s target
    let idle_conns = args.get_usize("idle", 256);
    let json_path = args.get("json").map(|s| s.to_string());

    // Either drive an external server or bring one up in-process (wire
    // listener + HTTP metrics listener on ephemeral ports).
    let (addr, http_addr, local) = match args.get("port") {
        Some(_) => {
            let port = args.get_port("port", 7070);
            let http = args
                .get("metrics-port")
                .map(|_| format!("127.0.0.1:{}", args.get_port("metrics-port", 7071)));
            (format!("127.0.0.1:{port}"), http, None)
        }
        None => {
            let svc = FheService::new(
                ArchConfig::default(),
                SchedulerConfig {
                    max_batch: args.get_usize("max-batch", 8),
                    max_delay: Duration::from_millis(args.get_u64("max-delay-ms", 3)),
                    max_queue: args.get_usize("max-queue", 4096),
                    max_tenant_inflight: 0,
                },
            );
            let handle = server::spawn_with(
                "127.0.0.1:0",
                Some("127.0.0.1:0"),
                svc.clone(),
                server::ServeOptions {
                    workers: args.get_usize("workers", 8),
                    ..server::ServeOptions::default()
                },
            )
            .expect("bind ephemeral ports");
            println!(
                "in-process server on {} (metrics http://{}/metrics)",
                handle.addr,
                handle.http_addr.expect("http listener")
            );
            let http = handle.http_addr.map(|a| a.to_string());
            (handle.addr.to_string(), http, Some((svc, handle)))
        }
    };

    // Readiness + scrape-window baseline: /healthz proves the HTTP
    // listener is live before the fleet fires, and one /metrics scrape
    // advances the `*_delta` histogram baselines so the post-run
    // snapshot's delta figures cover exactly this run — even against a
    // long-lived external server that has absorbed earlier traffic.
    if let Some(http) = &http_addr {
        let health = http_get(http, "/healthz").expect("GET /healthz");
        assert!(
            health.contains("\"status\": \"ok\""),
            "healthz did not report ok: {health}"
        );
        println!("GET /healthz OK ({} bytes)", health.len());
        let _ = http_get(http, "/metrics");
    }

    // Idle block: raw connections that never send a byte. Under the
    // readiness loop they cost two empty buffers each and zero threads;
    // under thread-per-connection they would each pin a thread.
    let mut idle: Vec<TcpStream> = Vec::with_capacity(idle_conns);
    for _ in 0..idle_conns {
        match TcpStream::connect(&addr) {
            Ok(s) => idle.push(s),
            Err(_) => break,
        }
    }
    println!("fleet: {tenants} active tenants, {} idle connections", idle.len());

    // Fleet-wide open-loop schedule: `rate` ops/s spread across the
    // fleet; tenant i's k-th op is due at phase(i) + k * interval where
    // interval = tenants / rate seconds (per tenant).
    let interval = Duration::from_secs_f64(tenants as f64 / rate as f64);
    let phase_step = Duration::from_secs_f64(1.0 / rate as f64);

    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let errors = Arc::new(AtomicU64::new(0));
    let t_start = Instant::now() + Duration::from_millis(200);

    std::thread::scope(|s| {
        for i in 0..tenants {
            let addr = addr.clone();
            let latencies = latencies.clone();
            let errors = errors.clone();
            s.spawn(move || {
                let mut client = match ServiceClient::connect(
                    &addr,
                    1000 + i as u64,
                    CkksParams::func_tiny(),
                    0xF1EE7 + i as u64,
                ) {
                    Ok(c) => c,
                    Err(_) => {
                        errors.fetch_add(ops_per_tenant as u64, Ordering::Relaxed);
                        return;
                    }
                };
                let slots = client.ctx.encoder.slots();
                let z: Vec<f64> = (0..slots).map(|j| 0.01 * ((i + j) % 11) as f64).collect();
                let ct = client.encrypt(&z, 3);
                // Warm-up (materializes this tenant's Galois key server
                // side) before the timed window opens.
                let _ = client.rotate(&ct, 1);
                let phase = phase_step * i as u32;
                for k in 0..ops_per_tenant {
                    let due = t_start + phase + interval * k as u32;
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    // Alternate rotate/add: same-shape ops from different
                    // tenants coalesce into mixed bank-pool batches.
                    let res = if k % 2 == 0 {
                        client.rotate(&ct, 1)
                    } else {
                        client.add(&ct, &ct)
                    };
                    match res {
                        Ok(_) => {
                            let ms = due.elapsed().as_secs_f64() * 1e3;
                            latencies.lock().unwrap().push(ms);
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let elapsed = t_start.elapsed().as_secs_f64();
    drop(idle);

    let mut lats = latencies.lock().unwrap().clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let completed = lats.len();
    let failed = errors.load(Ordering::Relaxed);
    assert!(completed > 0, "no op completed — server unreachable?");
    let pct = |p: f64| lats[((completed as f64 * p) as usize).min(completed - 1)];
    let p50 = pct(0.50);
    let p99 = pct(0.99);
    let sustained = completed as f64 / elapsed;
    println!(
        "completed {completed} ops ({failed} failed) in {elapsed:.2}s: \
         p50 {p50:.1} ms, p99 {p99:.1} ms, sustained {sustained:.1} ops/s"
    );

    // One traced probe op: stamp a trace id on the wire, run a rotate,
    // then pull the stitched trace back out of `/spans?trace=<id>` —
    // request → queue-wait → batch-exec linked end-to-end over TCP.
    let mut probe = ServiceClient::connect(&addr, 1000, CkksParams::func_tiny(), 0xF1EE7)
        .expect("metrics probe");
    let trace_id: u64 = 0xF1EE7_000 + tenants as u64;
    probe.set_trace(trace_id);
    {
        let slots = probe.ctx.encoder.slots();
        let z: Vec<f64> = vec![0.05; slots];
        let ct = probe.encrypt(&z, 3);
        probe.rotate(&ct, 1).expect("traced probe rotate");
    }
    probe.set_trace(0);

    // Scrape the HTTP endpoints (proves the plain-GET paths e2e) and the
    // wire-level snapshot for batching evidence. The first /metrics body
    // after the run is the one the bench figures come from: its `*_delta`
    // window spans exactly the load (the pre-run scrape set the
    // baseline).
    let mut mdoc_http: Option<Json> = None;
    if let Some(http) = &http_addr {
        let body = http_get(http, "/metrics").expect("GET /metrics");
        assert!(
            body.contains("\"batches\""),
            "metrics endpoint returned no scheduler snapshot: {body}"
        );
        mdoc_http = Some(Json::parse(&body).expect("metrics JSON parses"));
        println!("GET /metrics OK ({} bytes)", body.len());
        let prom = http_get(http, "/metrics/prometheus").expect("GET /metrics/prometheus");
        assert!(
            prom.contains("_bucket{le=") && prom.contains("# TYPE"),
            "prometheus exposition carries no histogram buckets: {prom}"
        );
        assert!(
            prom.contains("calib_factor_computation"),
            "prometheus exposition carries no calibration gauges: {prom}"
        );
        println!("GET /metrics/prometheus OK ({} bytes)", prom.len());
        let spans = http_get(http, "/spans").expect("GET /spans");
        assert!(
            spans.contains("\"traceEvents\""),
            "span endpoint returned no trace document: {spans}"
        );
        println!("GET /spans OK ({} bytes)", spans.len());
        let stitched = http_get(http, &format!("/spans?trace={trace_id}"))
            .expect("GET /spans?trace=");
        for name in ["\"request\"", "\"queue-wait\"", "\"batch-exec\""] {
            assert!(
                stitched.contains(name),
                "trace {trace_id} is missing its {name} span: {stitched}"
            );
        }
        println!("GET /spans?trace={trace_id} OK ({} bytes)", stitched.len());
    }
    let metrics_text = probe.metrics().expect("metrics");
    println!("scheduler metrics:\n{metrics_text}");
    // Server-side observability figures for the bench artifact: the
    // scheduler's own queue-wait/exec p99s and the running cost-model
    // drift ratios (raw and calibration-corrected), straight from the
    // metrics snapshot (works identically for in-process and external
    // servers). Prefer the HTTP body scraped right after the run so the
    // delta figures cover the load window.
    let mdoc = mdoc_http.unwrap_or_else(|| Json::parse(&metrics_text).expect("metrics JSON parses"));
    let figure = |key: &str| -> f64 {
        mdoc.field(key)
            .ok()
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let queue_wait_p99 = figure("queue_wait_p99_ms");
    let exec_p99 = figure("exec_p99_ms");
    let drift = figure("cost_model_drift_ratio");
    let calibrated = figure("calibrated_drift_ratio");
    let queue_wait_delta = figure("queue_wait_p99_ms_delta");
    let exec_delta = figure("exec_p99_ms_delta");
    println!(
        "server obs: queue-wait p99 {queue_wait_p99:.3} ms (window {queue_wait_delta:.3}), \
         exec p99 {exec_p99:.3} ms (window {exec_delta:.3}), \
         cost-model drift ratio {drift:.3} (calibrated {calibrated:.3})"
    );

    if let Some(path) = json_path {
        merge_bench_json(
            &path, tenants, idle_conns, p50, p99, sustained, queue_wait_p99, exec_p99, drift,
            calibrated,
        );
        println!(
            "recorded serve_p50_ms/serve_p99_ms/serve_sustained_ops_per_s/\
             serve_queue_wait_p99_ms/serve_exec_p99_ms/cost_model_drift_ratio/\
             calibrated_drift_ratio into {path}"
        );
    }

    if let Some((svc, handle)) = local {
        handle.stop();
        svc.shutdown();
    }
    println!("load_harness OK");
}

/// Minimal HTTP GET against the metrics listener; returns the body.
fn http_get(addr: &str, path: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\n\r\n").as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    match raw.split_once("\r\n\r\n") {
        Some((head, body)) if head.starts_with("HTTP/1.1 200") => Ok(body.to_string()),
        _ => Err(std::io::Error::other(format!("bad response: {raw}"))),
    }
}

/// Merge the serving figures into the bench JSON, preserving whatever
/// other figures the document already holds (the hotpath bench and this
/// harness share one artifact).
#[allow(clippy::too_many_arguments)]
fn merge_bench_json(
    path: &str,
    tenants: usize,
    idle: usize,
    p50: f64,
    p99: f64,
    ops_s: f64,
    queue_wait_p99: f64,
    exec_p99: f64,
    drift: f64,
    calibrated: f64,
) {
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text).unwrap_or_else(|_| Json::Object(Vec::new())),
        Err(_) => Json::Object(Vec::new()),
    };
    if !matches!(doc, Json::Object(_)) {
        doc = Json::Object(Vec::new());
    }
    if let Json::Object(fields) = &mut doc {
        let mut set = |key: &str, val: Json| {
            if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val;
            } else {
                fields.push((key.to_string(), val));
            }
        };
        set("serve_tenants", Json::Num(tenants as u64));
        set("serve_idle_conns", Json::Num(idle as u64));
        set("serve_p50_ms", Json::Float(p50));
        set("serve_p99_ms", Json::Float(p99));
        set("serve_sustained_ops_per_s", Json::Float(ops_s));
        set("serve_queue_wait_p99_ms", Json::Float(queue_wait_p99));
        set("serve_exec_p99_ms", Json::Float(exec_p99));
        set("cost_model_drift_ratio", Json::Float(drift));
        set("calibrated_drift_ratio", Json::Float(calibrated));
    }
    std::fs::write(path, doc.write_pretty()).expect("write bench json");
}
