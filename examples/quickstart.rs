//! Quickstart: encrypt two vectors, multiply and rotate homomorphically,
//! decrypt and verify — then report what the same work costs on the
//! simulated FHEmem accelerator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fhemem::coordinator::Coordinator;
use fhemem::params::CkksParams;
use fhemem::sim::ArchConfig;
use std::path::Path;

fn main() {
    // Functional CKKS context + the paper's lowest-EDAP accelerator.
    let coord = Coordinator::new(
        CkksParams::func_tiny(),
        ArchConfig::default(), // ARx4-4k
        Some(Path::new("artifacts")),
    );
    println!("backend: {}", coord.backend_name());

    let slots = coord.ctx.encoder.slots();
    let xs: Vec<f64> = (0..slots).map(|i| 0.1 * ((i % 7) as f64 - 3.0)).collect();
    let ys: Vec<f64> = (0..slots).map(|i| 0.05 * ((i % 5) as f64)).collect();

    let cx = coord.eval.encrypt_real(&xs, 3);
    let cy = coord.eval.encrypt_real(&ys, 3);

    let sum = coord.hadd(&cx, &cy);
    let prod = coord.hmul(&cx, &cy);
    let rot = coord.rotate(&cx, 2);

    let d_sum = coord.eval.decrypt_real(&sum);
    let d_prod = coord.eval.decrypt_real(&prod);
    let d_rot = coord.eval.decrypt_real(&rot);

    let mut worst = 0.0f64;
    for i in 0..slots {
        worst = worst.max((d_sum[i] - (xs[i] + ys[i])).abs());
        worst = worst.max((d_prod[i] - xs[i] * ys[i]).abs());
        worst = worst.max((d_rot[i] - xs[(i + 2) % slots]).abs());
    }
    println!("worst slot error across add/mul/rotate: {worst:.2e}");
    assert!(worst < 1e-2, "homomorphic results diverged");

    println!(
        "simulated cost on {}: {:.2} us, {:.3e} J for {} ops",
        coord.arch.name(),
        coord.simulated_seconds() * 1e6,
        coord.simulated_energy_j(),
        coord.metrics.ops.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!("quickstart OK");
}
